//! Task Arithmetic (Ilharco et al., ICLR 2023) — the foundational method:
//! theta_MTL = theta_pre + lambda * sum_t tau_t with a single shared
//! coefficient.

use anyhow::Result;

use super::{MergedModel, Merger};
use crate::checkpoint::Checkpoint;

#[derive(Clone, Copy, Debug)]
pub struct TaskArithmetic {
    pub lambda: f32,
}

impl Default for TaskArithmetic {
    fn default() -> Self {
        // lambda = 0.3 is the standard validated value for 8-task ViT
        // suites (paper Section 3.1 protocol).
        Self { lambda: 0.3 }
    }
}

impl TaskArithmetic {
    pub fn new(lambda: f32) -> Self {
        Self { lambda }
    }
}

impl Merger for TaskArithmetic {
    fn name(&self) -> &'static str {
        "task_arithmetic"
    }

    fn merge(&self, pre: &Checkpoint, taus: &[Checkpoint]) -> Result<MergedModel> {
        let mut out = pre.clone();
        for tau in taus {
            out.axpy(self.lambda, tau)?;
        }
        Ok(MergedModel::Shared(out))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::fixture;
    use super::*;

    #[test]
    fn zero_tasks_returns_pre() {
        let (pre, _) = fixture(0, 1);
        let m = TaskArithmetic::default().merge(&pre, &[]).unwrap();
        assert_eq!(m.for_task(0), &pre);
    }

    #[test]
    fn single_task_lambda_one_recovers_finetuned() {
        let (pre, taus) = fixture(1, 2);
        let m = TaskArithmetic::new(1.0).merge(&pre, &taus).unwrap();
        let ft = pre.add(&taus[0]).unwrap();
        assert!(m.for_task(0).l2_dist(&ft).unwrap() < 1e-5);
    }

    #[test]
    fn linearity_in_lambda() {
        let (pre, taus) = fixture(3, 3);
        let m1 = TaskArithmetic::new(0.2).merge(&pre, &taus).unwrap();
        let m2 = TaskArithmetic::new(0.4).merge(&pre, &taus).unwrap();
        // (m2 - pre) == 2 * (m1 - pre)
        let d1 = m1.for_task(0).sub(&pre).unwrap();
        let d2 = m2.for_task(0).sub(&pre).unwrap();
        assert!(d2.l2_dist(&d1.scale(2.0)).unwrap() < 1e-5);
    }
}
