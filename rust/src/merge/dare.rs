//! DARE (Drop-And-REscale, Yu et al., ICML 2024) — the sparsification
//! baseline the paper's related-work section cites alongside Ties: drop a
//! random fraction p of each task vector's entries and rescale the
//! survivors by 1/(1-p), keeping the merge an unbiased estimator of task
//! arithmetic while decimating interference.

use anyhow::Result;

use super::{MergedModel, Merger};
use crate::checkpoint::Checkpoint;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct Dare {
    /// Task-arithmetic coefficient applied after drop/rescale.
    pub lambda: f32,
    /// Fraction of entries dropped (the DARE paper sweeps up to 0.99).
    pub drop_rate: f32,
    /// Seed for the drop masks (deterministic merges).
    pub seed: u64,
}

impl Default for Dare {
    fn default() -> Self {
        Self { lambda: 0.3, drop_rate: 0.9, seed: 0xDA7E }
    }
}

impl Dare {
    pub fn new(lambda: f32, drop_rate: f32, seed: u64) -> Self {
        Self { lambda, drop_rate, seed }
    }

    /// Drop-and-rescale one task vector.
    fn drop_rescale(&self, tau: &Checkpoint, rng: &mut Rng) -> Checkpoint {
        let keep = 1.0 - self.drop_rate;
        let rescale = if keep > 0.0 { 1.0 / keep } else { 0.0 };
        let mut out = tau.clone();
        for (_, t) in out.iter_mut() {
            for v in t.data_mut() {
                if rng.f32() < self.drop_rate {
                    *v = 0.0;
                } else {
                    *v *= rescale;
                }
            }
        }
        out
    }
}

impl Merger for Dare {
    fn name(&self) -> &'static str {
        "dare"
    }

    fn merge(&self, pre: &Checkpoint, taus: &[Checkpoint]) -> Result<MergedModel> {
        let mut merged = pre.clone();
        let mut rng = Rng::new(self.seed);
        for (t, tau) in taus.iter().enumerate() {
            let mut fork = rng.fork(t as u64);
            let sparse = self.drop_rescale(tau, &mut fork);
            merged.axpy(self.lambda, &sparse)?;
        }
        Ok(MergedModel::Shared(merged))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::fixture;
    use super::*;

    #[test]
    fn zero_drop_equals_task_arithmetic() {
        let (pre, taus) = fixture(3, 21);
        let dare = Dare::new(0.3, 0.0, 1);
        let ta = super::super::TaskArithmetic::new(0.3);
        let a = dare.merge(&pre, &taus).unwrap();
        let b = ta.merge(&pre, &taus).unwrap();
        assert!(a.for_task(0).l2_dist(b.for_task(0)).unwrap() < 1e-5);
    }

    #[test]
    fn drop_rate_controls_sparsity() {
        let (_, taus) = fixture(1, 22);
        let dare = Dare::new(0.3, 0.9, 2);
        let mut rng = Rng::new(0);
        let sparse = dare.drop_rescale(&taus[0], &mut rng);
        let total: usize = sparse.numel();
        let zeros: usize = sparse
            .iter()
            .map(|(_, t)| t.data().iter().filter(|&&v| v == 0.0).count())
            .sum();
        let frac = zeros as f64 / total as f64;
        assert!((frac - 0.9).abs() < 0.05, "sparsity {frac} far from 0.9");
    }

    #[test]
    fn rescale_preserves_expected_norm() {
        // E[drop_rescale(tau)] = tau: the mean over many seeds converges.
        let (_, taus) = fixture(1, 23);
        let dare = Dare::new(0.3, 0.5, 3);
        let mut acc = taus[0].scale(0.0);
        let n = 64;
        for s in 0..n {
            let mut rng = Rng::new(s);
            acc.axpy(1.0 / n as f32, &dare.drop_rescale(&taus[0], &mut rng))
                .unwrap();
        }
        let rel = acc.l2_dist(&taus[0]).unwrap()
            / taus[0].l2_dist(&taus[0].scale(0.0)).unwrap();
        assert!(rel < 0.25, "mean of drop_rescale should approach tau (rel {rel})");
    }

    #[test]
    fn deterministic_given_seed() {
        let (pre, taus) = fixture(2, 24);
        let a = Dare::default().merge(&pre, &taus).unwrap();
        let b = Dare::default().merge(&pre, &taus).unwrap();
        assert_eq!(a.for_task(0), b.for_task(0));
    }
}
