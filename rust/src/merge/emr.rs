//! EMR-Merging (Huang et al., NeurIPS 2024): Elect a unified task vector,
//! then per-task binary Masks and Rescaling factors modulate it at
//! inference — tuning-free, but the output is a per-task model family.
//!
//! Elect: per parameter, the unified sign is the sign of sum_t tau_t; the
//! unified magnitude is the maximum |tau_t| among sign-agreeing tasks.
//! Mask:  M_t = 1[ sign(tau_t) == sign(tau_uni) && tau_t != 0 ].
//! Rescale: lambda_t = sum|tau_t| / sum|M_t * tau_uni|.

use anyhow::Result;

use super::{MergedModel, Merger};
use crate::checkpoint::Checkpoint;

#[derive(Clone, Copy, Debug, Default)]
pub struct EmrMerging;

/// Intermediate representation exposing EMR's storage story (the unified
/// vector is shared; masks are 1 bit/param/task; rescales are scalars).
#[derive(Clone, Debug)]
pub struct EmrArtifacts {
    pub tau_uni: Checkpoint,
    /// Per task: bit masks stored as `Vec<bool>` per tensor name order.
    pub masks: Vec<Vec<bool>>,
    pub rescales: Vec<f32>,
}

impl EmrMerging {
    /// Compute the elect/mask/rescale decomposition.
    pub fn artifacts(&self, taus: &[Checkpoint]) -> Result<EmrArtifacts> {
        anyhow::ensure!(!taus.is_empty(), "EMR needs at least one task");
        // Elect the unified task vector.
        let mut tau_uni = taus[0].scale(0.0);
        for (name, uni_t) in tau_uni.iter_mut() {
            let n = uni_t.numel();
            let dst = uni_t.data_mut();
            for i in 0..n {
                let mut sum = 0.0f64;
                for tau in taus {
                    sum += tau.get(name)?.data()[i] as f64;
                }
                let sign = if sum >= 0.0 { 1.0f32 } else { -1.0f32 };
                let mut mag = 0.0f32;
                for tau in taus {
                    let v = tau.get(name)?.data()[i];
                    if v.signum() == sign && v.abs() > mag {
                        mag = v.abs();
                    }
                }
                dst[i] = sign * mag;
            }
        }
        // Per-task masks and rescales.
        let mut masks = Vec::with_capacity(taus.len());
        let mut rescales = Vec::with_capacity(taus.len());
        for tau in taus {
            let mut mask = Vec::with_capacity(tau.numel());
            let mut sum_tau = 0.0f64;
            let mut sum_masked_uni = 0.0f64;
            for (name, t) in tau.iter() {
                let uni = tau_uni.get(name)?;
                for i in 0..t.numel() {
                    let v = t.data()[i];
                    let u = uni.data()[i];
                    let m = v != 0.0 && v.signum() == u.signum();
                    mask.push(m);
                    sum_tau += v.abs() as f64;
                    if m {
                        sum_masked_uni += u.abs() as f64;
                    }
                }
            }
            let rescale = if sum_masked_uni > 0.0 {
                (sum_tau / sum_masked_uni) as f32
            } else {
                1.0
            };
            masks.push(mask);
            rescales.push(rescale);
        }
        Ok(EmrArtifacts { tau_uni, masks, rescales })
    }

    /// Reconstruct the model for task t: pre + lambda_t * (M_t ∘ tau_uni).
    pub fn model_for_task(
        &self,
        pre: &Checkpoint,
        art: &EmrArtifacts,
        t: usize,
    ) -> Result<Checkpoint> {
        let mut out = pre.clone();
        let mask = &art.masks[t];
        let lam = art.rescales[t];
        let mut off = 0usize;
        for (name, out_t) in out.iter_mut() {
            let uni = art.tau_uni.get(name)?;
            let dst = out_t.data_mut();
            for i in 0..dst.len() {
                if mask[off + i] {
                    dst[i] += lam * uni.data()[i];
                }
            }
            off += dst.len();
        }
        Ok(out)
    }
}

impl Merger for EmrMerging {
    fn name(&self) -> &'static str {
        "emr_merging"
    }

    fn merge(&self, pre: &Checkpoint, taus: &[Checkpoint]) -> Result<MergedModel> {
        let art = self.artifacts(taus)?;
        let models = (0..taus.len())
            .map(|t| self.model_for_task(pre, &art, t))
            .collect::<Result<Vec<_>>>()?;
        Ok(MergedModel::PerTask(models))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::fixture;
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn single_task_mask_recovers_finetuned_closely() {
        // With one task, tau_uni == tau, mask is all-nonzero entries,
        // rescale == 1 -> model == fine-tuned checkpoint.
        let (pre, taus) = fixture(1, 17);
        let emr = EmrMerging;
        let m = emr.merge(&pre, &taus[..1]).unwrap();
        let ft = pre.add(&taus[0]).unwrap();
        assert!(m.for_task(0).l2_dist(&ft).unwrap() < 1e-4);
    }

    #[test]
    fn unified_magnitude_is_max_of_agreeing() {
        let mk = |vals: [f32; 3]| {
            let mut c = Checkpoint::new();
            c.insert("w", Tensor::from_vec(vals.to_vec()));
            c
        };
        let taus = vec![mk([1.0, -0.5, 0.2]), mk([3.0, -1.5, -0.4])];
        let art = EmrMerging.artifacts(&taus).unwrap();
        let uni = art.tau_uni.get("w").unwrap();
        // w0: sum=4>0, max agreeing = 3; w1: sum=-2<0 -> -1.5;
        // w2: sum=-0.2<0 -> -0.4
        assert_eq!(uni.data(), &[3.0, -1.5, -0.4]);
    }

    #[test]
    fn per_task_models_differ() {
        let (pre, taus) = fixture(3, 18);
        let m = EmrMerging.merge(&pre, &taus).unwrap();
        assert_eq!(m.n_variants(), 3);
        assert!(m.for_task(0).l2_dist(m.for_task(1)).unwrap() > 1e-6);
    }

    #[test]
    fn rescale_restores_l1_mass() {
        let (_, taus) = fixture(4, 19);
        let art = EmrMerging.artifacts(&taus).unwrap();
        for (t, tau) in taus.iter().enumerate() {
            let mut sum_tau = 0.0f64;
            for (_, x) in tau.iter() {
                sum_tau += x.data().iter().map(|v| v.abs() as f64).sum::<f64>();
            }
            // ||lambda_t * M_t o tau_uni||_1 == ||tau_t||_1 by construction.
            let mut off = 0usize;
            let mut sum_masked = 0.0f64;
            for (name, x) in tau.iter() {
                let uni = art.tau_uni.get(name).unwrap();
                for i in 0..x.numel() {
                    if art.masks[t][off + i] {
                        sum_masked += uni.data()[i].abs() as f64;
                    }
                }
                off += x.numel();
            }
            let lhs = art.rescales[t] as f64 * sum_masked;
            assert!((lhs - sum_tau).abs() / sum_tau < 1e-4);
        }
    }
}
