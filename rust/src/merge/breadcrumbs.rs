//! Model Breadcrumbs (Davari & Belilovsky, ECCV 2024): layer-wise masking
//! that removes both extreme outliers (top beta fraction by magnitude) and
//! negligible values (bottom gamma fraction) from each task vector before
//! summing.

use anyhow::Result;

use super::{MergedModel, Merger};
use crate::checkpoint::Checkpoint;

#[derive(Clone, Copy, Debug)]
pub struct Breadcrumbs {
    pub lambda: f32,
    /// Fraction of largest-magnitude weights dropped per tensor.
    pub beta: f64,
    /// Fraction of smallest-magnitude weights dropped per tensor.
    pub gamma: f64,
}

impl Default for Breadcrumbs {
    fn default() -> Self {
        Self { lambda: 0.3, beta: 0.01, gamma: 0.85 }
    }
}

impl Breadcrumbs {
    /// Keep only magnitudes inside (gamma-quantile, (1-beta)-quantile].
    fn mask(&self, tau: &Checkpoint) -> Checkpoint {
        let mut out = Checkpoint::new();
        for (name, t) in tau.iter() {
            let lo = t.abs_quantile(self.gamma);
            let hi = t.abs_quantile(1.0 - self.beta);
            out.insert(
                name,
                t.map(|x| {
                    let a = x.abs();
                    if a > lo && a <= hi {
                        x
                    } else {
                        0.0
                    }
                }),
            );
        }
        out
    }
}

impl Merger for Breadcrumbs {
    fn name(&self) -> &'static str {
        "breadcrumbs"
    }

    fn merge(&self, pre: &Checkpoint, taus: &[Checkpoint]) -> Result<MergedModel> {
        let mut out = pre.clone();
        for tau in taus {
            out.axpy(self.lambda, &self.mask(tau))?;
        }
        Ok(MergedModel::Shared(out))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::fixture;
    use super::*;

    #[test]
    fn mask_drops_outliers_and_small_values() {
        let (_, taus) = fixture(1, 14);
        let bc = Breadcrumbs { lambda: 0.3, beta: 0.05, gamma: 0.5 };
        let masked = bc.mask(&taus[0]);
        for (name, t) in masked.iter() {
            let src = taus[0].get(name).unwrap();
            // Sparsity should be roughly gamma + beta.
            let sp = t.sparsity();
            assert!(
                sp > 0.4 && sp < 0.75,
                "{name}: sparsity {sp} out of expected band"
            );
            // Largest original magnitude must be gone.
            let (_, hi_src) = src.map(|x| x.abs()).min_max();
            let (_, hi_out) = t.map(|x| x.abs()).min_max();
            assert!(hi_out < hi_src);
        }
    }

    #[test]
    fn beta_zero_gamma_zero_is_task_arithmetic() {
        let (pre, taus) = fixture(2, 15);
        let bc = Breadcrumbs { lambda: 0.3, beta: 0.0, gamma: 0.0 };
        let m = bc.merge(&pre, &taus).unwrap();
        let ta = super::super::TaskArithmetic::new(0.3)
            .merge(&pre, &taus)
            .unwrap();
        // gamma=0 drops only values with |x| <= min magnitude... close to
        // none for continuous data except exact min; allow tiny diff.
        let d = m.for_task(0).l2_dist(ta.for_task(0)).unwrap();
        let norm = ta.for_task(0).sub(&pre).unwrap();
        let scale: f64 = norm.iter().map(|(_, t)| t.l2_norm()).sum();
        assert!(d < 0.05 * scale.max(1e-9), "d={d}");
    }

    #[test]
    fn masked_delta_is_subset_of_full_delta() {
        let (pre, taus) = fixture(3, 16);
        let bc = Breadcrumbs::default();
        let m = bc.merge(&pre, &taus).unwrap();
        let delta = m.for_task(0).sub(&pre).unwrap();
        // Every nonzero coordinate of the merged delta must be explainable
        // by the sum of masked taus (trivially true by construction; check
        // the magnitude is bounded by sum of |tau| coordinates).
        for (name, t) in delta.iter() {
            for i in 0..t.numel() {
                let bound: f32 = taus
                    .iter()
                    .map(|tau| tau.get(name).unwrap().data()[i].abs())
                    .sum();
                assert!(t.data()[i].abs() <= bc.lambda * bound + 1e-6);
            }
        }
    }
}
