//! Evaluation metrics and harnesses.
//!
//! Classification accuracy / prediction entropy (AdaMerging's objective),
//! the three dense-prediction metrics of Table 3 (mIoU + pixel accuracy,
//! absolute & relative depth error, mean angular error), the
//! target-vs-cross-task protocol of Table 4, and the loss-landscape grid
//! of Fig. 8.

pub mod landscape;

use anyhow::Result;

use crate::checkpoint::Checkpoint;
use crate::data::classify::ClassifyTask;
use crate::data::dense::{DenseBatch, DenseTaskKind};
use crate::data::{DensePreset, VitPreset};
use crate::runtime::{self, Runtime};
use crate::tensor::Tensor;

/// Default evaluation-set size per classification task.
pub const EVAL_N: usize = 512;

/// Argmax over the last axis of a [n, c] tensor.
fn argmax_rows(logits: &Tensor) -> Vec<usize> {
    let c = *logits.shape().last().unwrap();
    logits
        .data()
        .chunks_exact(c)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        })
        .collect()
}

/// Mean softmax entropy of a [n, c] logits tensor (nats).
pub fn mean_entropy(logits: &Tensor) -> f64 {
    let c = *logits.shape().last().unwrap();
    let mut acc = 0.0f64;
    let mut rows = 0usize;
    for row in logits.data().chunks_exact(c) {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f64> = row.iter().map(|&v| ((v - m) as f64).exp()).collect();
        let z: f64 = exps.iter().sum();
        let mut h = 0.0f64;
        for e in &exps {
            let p = e / z;
            if p > 0.0 {
                h -= p * p.ln();
            }
        }
        acc += h;
        rows += 1;
    }
    acc / rows.max(1) as f64
}

/// Mean softmax entropy of logits after per-row scale normalization
/// (each row divided by its std).  Plain entropy can be gamed by scaling
/// all logits up (larger merge coefficients -> larger activations ->
/// lower entropy with no accuracy change); normalizing makes the
/// AdaMerging objective sensitive to class *separation* instead.
pub fn mean_entropy_norm(logits: &Tensor) -> f64 {
    let c = *logits.shape().last().unwrap();
    let mut normed = logits.clone();
    for row in normed.data_mut().chunks_exact_mut(c) {
        let mean = row.iter().sum::<f32>() / c as f32;
        let var =
            row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / c as f32;
        let std = var.sqrt().max(1e-6);
        for v in row.iter_mut() {
            *v = (*v - mean) / std;
        }
    }
    mean_entropy(&normed)
}

/// Mean cross-entropy loss of [n, c] logits against labels.
pub fn mean_ce(logits: &Tensor, labels: &[i32]) -> f64 {
    let c = *logits.shape().last().unwrap();
    let mut acc = 0.0f64;
    for (row, &y) in logits.data().chunks_exact(c).zip(labels) {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f64 = row.iter().map(|&v| ((v - m) as f64).exp()).sum();
        let logp = (row[y as usize] - m) as f64 - z.ln();
        acc -= logp;
    }
    acc / labels.len().max(1) as f64
}

/// Run the eval-batch forward artifact over a full set, padding the tail.
pub fn batched_logits(
    rt: &Runtime,
    preset: &VitPreset,
    ck: &Checkpoint,
    head: &Tensor,
    x: &Tensor,
) -> Result<Tensor> {
    let b = preset.eval_batch;
    let art = rt.load(&format!("{}_forward_b{}", preset.name, b))?;
    let n = x.shape()[0];
    let img = preset.tokens * preset.token_dim;
    let c = head.shape()[1];
    let mut out = Tensor::zeros(&[n, c]);
    let mut chunk = Tensor::zeros(&[b, preset.tokens, preset.token_dim]);
    let mut start = 0usize;
    while start < n {
        let take = (n - start).min(b);
        chunk.data_mut()[..take * img]
            .copy_from_slice(&x.data()[start * img..(start + take) * img]);
        // tail padding: zeros (results discarded)
        for v in chunk.data_mut()[take * img..].iter_mut() {
            *v = 0.0;
        }
        let logits = runtime::forward_logits(&art, ck, head, &chunk)?;
        out.data_mut()[start * c..(start + take) * c]
            .copy_from_slice(&logits.data()[..take * c]);
        start += take;
    }
    Ok(out)
}

/// Accuracy (%) of `ck` on a classification task's held-out set.
pub fn classify_accuracy(
    rt: &Runtime,
    preset: &VitPreset,
    ck: &Checkpoint,
    task: &ClassifyTask,
) -> Result<f64> {
    let (x, y) = task.eval_set(EVAL_N);
    let logits = batched_logits(rt, preset, ck, &task.head, &x)?;
    let pred = argmax_rows(&logits);
    let correct = pred
        .iter()
        .zip(&y)
        .filter(|(p, &t)| **p == t as usize)
        .count();
    Ok(100.0 * correct as f64 / y.len() as f64)
}

/// Mean prediction entropy of `ck` on a task's (unlabeled) eval inputs —
/// the AdaMerging test-time objective.
pub fn classify_entropy(
    rt: &Runtime,
    preset: &VitPreset,
    ck: &Checkpoint,
    task: &ClassifyTask,
    n: usize,
) -> Result<f64> {
    let (x, _) = task.eval_set(n);
    let logits = batched_logits(rt, preset, ck, &task.head, &x)?;
    Ok(mean_entropy(&logits))
}

/// Scale-normalized variant of [`classify_entropy`] — the AdaMerging
/// test-time objective (see [`mean_entropy_norm`]).
pub fn classify_entropy_norm(
    rt: &Runtime,
    preset: &VitPreset,
    ck: &Checkpoint,
    task: &ClassifyTask,
    n: usize,
) -> Result<f64> {
    let (x, _) = task.eval_set(n);
    let logits = batched_logits(rt, preset, ck, &task.head, &x)?;
    Ok(mean_entropy_norm(&logits))
}

/// Mean CE loss of `ck` on a task (loss-landscape probe).
pub fn classify_loss(
    rt: &Runtime,
    preset: &VitPreset,
    ck: &Checkpoint,
    task: &ClassifyTask,
    n: usize,
) -> Result<f64> {
    let (x, y) = task.eval_set(n);
    let logits = batched_logits(rt, preset, ck, &task.head, &x)?;
    Ok(mean_ce(&logits, &y))
}

// ---------------------------------------------------------------------------
// Dense-prediction metrics (Table 3 / Table D)
// ---------------------------------------------------------------------------

/// Scores for one dense task evaluation.
#[derive(Clone, Copy, Debug, Default)]
pub struct DenseScores {
    pub miou: f64,
    pub pix_acc: f64,
    pub abs_err: f64,
    pub rel_err: f64,
    pub mean_angle: f64,
}

/// Evaluate `ck` on one dense task over `batches` deterministic batches.
pub fn dense_eval(
    rt: &Runtime,
    preset: &DensePreset,
    ck: &Checkpoint,
    kind: DenseTaskKind,
    head: &Tensor,
    batches: usize,
) -> Result<DenseScores> {
    let art = rt.load(&format!("dense_forward_{}_b{}", kind.name(), preset.batch))?;
    let mut scores = DenseScores::default();
    let nclass = preset.seg_classes;
    let mut inter = vec![0.0f64; nclass];
    let mut union = vec![0.0f64; nclass];
    let mut pix_correct = 0.0f64;
    let mut pix_total = 0.0f64;
    let mut abs_acc = 0.0f64;
    let mut rel_acc = 0.0f64;
    let mut ang_acc = 0.0f64;
    let mut n_px = 0.0f64;
    for bi in 0..batches {
        let batch: DenseBatch =
            crate::data::dense::eval_batch(preset, preset.batch, 5000 + bi as u64);
        let out = runtime::forward_logits(&art, ck, head, &batch.x)?;
        match kind {
            DenseTaskKind::Seg => {
                let pred = argmax_rows(&out); // rows are pixels
                for (p, &t) in pred.iter().zip(&batch.seg) {
                    let t = t as usize;
                    pix_total += 1.0;
                    if *p == t {
                        pix_correct += 1.0;
                        inter[t] += 1.0;
                    }
                    union[t] += 1.0;
                    if *p != t {
                        union[*p] += 1.0;
                    }
                }
            }
            DenseTaskKind::Depth => {
                for (o, t) in out.data().iter().zip(batch.depth.data()) {
                    abs_acc += (o - t).abs() as f64;
                    rel_acc += ((o - t).abs() / t.abs().max(1e-3)) as f64;
                    n_px += 1.0;
                }
            }
            DenseTaskKind::Normal => {
                for (o, t) in out.data().chunks_exact(3).zip(batch.normal.data().chunks_exact(3)) {
                    let dot: f32 = o.iter().zip(t).map(|(a, b)| a * b).sum();
                    let no: f32 = o.iter().map(|v| v * v).sum::<f32>().sqrt();
                    let nt: f32 = t.iter().map(|v| v * v).sum::<f32>().sqrt();
                    let cos = (dot / (no * nt + 1e-6)).clamp(-1.0, 1.0);
                    ang_acc += (cos as f64).acos().to_degrees();
                    n_px += 1.0;
                }
            }
        }
    }
    match kind {
        DenseTaskKind::Seg => {
            let mut miou = 0.0f64;
            let mut present = 0.0f64;
            for c in 0..nclass {
                if union[c] > 0.0 {
                    miou += inter[c] / union[c];
                    present += 1.0;
                }
            }
            scores.miou = 100.0 * miou / present.max(1.0);
            scores.pix_acc = 100.0 * pix_correct / pix_total.max(1.0);
        }
        DenseTaskKind::Depth => {
            scores.abs_err = 100.0 * abs_acc / n_px.max(1.0);
            scores.rel_err = 100.0 * rel_acc / n_px.max(1.0);
        }
        DenseTaskKind::Normal => {
            scores.mean_angle = ang_acc / n_px.max(1.0);
        }
    }
    Ok(scores)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_entropy() {
        let logits = Tensor::new(vec![2, 3], vec![0.0, 5.0, 0.0, 9.0, 0.0, 0.0]).unwrap();
        assert_eq!(argmax_rows(&logits), vec![1, 0]);
        // near-one-hot rows -> low entropy; uniform rows -> ln(3)
        let low = mean_entropy(&logits);
        let uni = Tensor::new(vec![1, 3], vec![1.0, 1.0, 1.0]).unwrap();
        let high = mean_entropy(&uni);
        assert!(low < 0.1);
        assert!((high - 3.0f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn entropy_norm_is_scale_invariant() {
        let a = Tensor::new(vec![1, 4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::new(vec![1, 4], vec![10.0, 20.0, 30.0, 40.0]).unwrap();
        assert!((mean_entropy_norm(&a) - mean_entropy_norm(&b)).abs() < 1e-6);
        // Plain entropy is NOT scale invariant (the gaming vector).
        assert!(mean_entropy(&b) < mean_entropy(&a));
    }

    #[test]
    fn ce_matches_manual() {
        let logits = Tensor::new(vec![1, 2], vec![0.0, 0.0]).unwrap();
        let ce = mean_ce(&logits, &[0]);
        assert!((ce - 2.0f64.ln()).abs() < 1e-9);
    }
}
