//! Loss-landscape grids (paper Fig. 8 / Appendix C.4).
//!
//! Following Garipov et al., we span a 2-D plane in weight space through
//! three anchors (pre-trained, task vector A, task vector B) and evaluate
//! the task loss on a `grid x grid` lattice.  The paper uses this to show
//! quantized task vectors drifting toward directions that help *other*
//! tasks.

use anyhow::Result;

use crate::checkpoint::Checkpoint;
use crate::data::classify::ClassifyTask;
use crate::data::VitPreset;
use crate::eval::classify_loss;
use crate::runtime::Runtime;

/// A computed loss grid plus its axis coefficients.
#[derive(Clone, Debug)]
pub struct LossGrid {
    pub grid: usize,
    /// alpha (axis 0) and beta (axis 1) coefficient ranges.
    pub alphas: Vec<f32>,
    pub betas: Vec<f32>,
    /// Row-major [grid, grid] losses.
    pub losses: Vec<f64>,
}

impl LossGrid {
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.losses[i * self.grid + j]
    }

    /// CSV dump (one row per alpha), for plotting outside.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("alpha\\beta");
        for b in &self.betas {
            s.push_str(&format!(",{b:.3}"));
        }
        s.push('\n');
        for (i, a) in self.alphas.iter().enumerate() {
            s.push_str(&format!("{a:.3}"));
            for j in 0..self.grid {
                s.push_str(&format!(",{:.4}", self.at(i, j)));
            }
            s.push('\n');
        }
        s
    }
}

/// Evaluate the loss of `pre + alpha*tau_a + beta*tau_b` on `task` over a
/// `grid x grid` lattice with coefficients in [lo, hi].
#[allow(clippy::too_many_arguments)]
pub fn loss_grid(
    rt: &Runtime,
    preset: &VitPreset,
    pre: &Checkpoint,
    tau_a: &Checkpoint,
    tau_b: &Checkpoint,
    task: &ClassifyTask,
    grid: usize,
    range: (f32, f32),
    eval_n: usize,
) -> Result<LossGrid> {
    let (lo, hi) = range;
    let coef = |k: usize| lo + (hi - lo) * k as f32 / (grid - 1).max(1) as f32;
    let alphas: Vec<f32> = (0..grid).map(coef).collect();
    let betas: Vec<f32> = (0..grid).map(coef).collect();
    let mut losses = Vec::with_capacity(grid * grid);
    for &a in &alphas {
        // Build the alpha component once per row.
        let mut row_base = pre.clone();
        row_base.axpy(a, tau_a)?;
        for &b in &betas {
            let mut ck = row_base.clone();
            ck.axpy(b, tau_b)?;
            losses.push(classify_loss(rt, preset, &ck, task, eval_n)?);
        }
    }
    Ok(LossGrid { grid, alphas, betas, losses })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_shape() {
        let g = LossGrid {
            grid: 2,
            alphas: vec![0.0, 1.0],
            betas: vec![0.0, 1.0],
            losses: vec![1.0, 2.0, 3.0, 4.0],
        };
        assert_eq!(g.at(1, 0), 3.0);
        let csv = g.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.contains("alpha\\beta"));
    }
}
