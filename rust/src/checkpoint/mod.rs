//! Named-tensor checkpoints and their on-disk container.
//!
//! A [`Checkpoint`] is an ordered map `name -> Tensor` holding a model
//! trunk's parameters.  Ordering is lexicographic by name — the same
//! contract as the Python side's `param_order()` — so flattening a
//! checkpoint here and flattening the pytree there produce identical
//! layouts, which the AOT manifests then cross-check shape-by-shape.

mod store;

pub use store::CheckpointStore;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::tensor::Tensor;

/// An ordered collection of named parameter tensors.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Checkpoint {
    tensors: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow!("checkpoint missing tensor {name:?}"))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.keys().map(|s| s.as_str())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.tensors.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn iter_mut(&mut self) -> impl Iterator<Item = (&str, &mut Tensor)> {
        self.tensors.iter_mut().map(|(k, v)| (k.as_str(), v))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total parameter count.
    pub fn numel(&self) -> usize {
        self.tensors.values().map(|t| t.numel()).sum()
    }

    /// Storage footprint at full precision (f32).
    pub fn fp32_bytes(&self) -> usize {
        self.numel() * 4
    }

    fn check_compatible(&self, other: &Checkpoint) -> Result<()> {
        if self.tensors.len() != other.tensors.len() {
            bail!(
                "checkpoint tensor-count mismatch: {} vs {}",
                self.tensors.len(),
                other.tensors.len()
            );
        }
        for (name, t) in &self.tensors {
            let o = other.get(name)?;
            if t.shape() != o.shape() {
                bail!(
                    "tensor {name:?} shape mismatch: {:?} vs {:?}",
                    t.shape(),
                    o.shape()
                );
            }
        }
        Ok(())
    }

    /// Elementwise difference `self - other` — a task vector when `self`
    /// is fine-tuned and `other` is pre-trained (tau = theta_ft - theta_pre).
    pub fn sub(&self, other: &Checkpoint) -> Result<Checkpoint> {
        self.check_compatible(other)?;
        let mut out = Checkpoint::new();
        for (name, t) in &self.tensors {
            out.insert(name, t.sub(other.get(name)?)?);
        }
        Ok(out)
    }

    /// Elementwise sum.
    pub fn add(&self, other: &Checkpoint) -> Result<Checkpoint> {
        self.check_compatible(other)?;
        let mut out = Checkpoint::new();
        for (name, t) in &self.tensors {
            out.insert(name, t.add(other.get(name)?)?);
        }
        Ok(out)
    }

    /// Scale every tensor by `s`.
    pub fn scale(&self, s: f32) -> Checkpoint {
        let mut out = Checkpoint::new();
        for (name, t) in &self.tensors {
            out.insert(name, t.scale(s));
        }
        out
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Checkpoint) -> Result<()> {
        self.check_compatible(other)?;
        for (name, t) in self.tensors.iter_mut() {
            t.axpy(alpha, other.get(name)?)?;
        }
        Ok(())
    }

    /// Average of several compatible checkpoints (theta_ft_avg in Eq. 4).
    pub fn average(cks: &[&Checkpoint]) -> Result<Checkpoint> {
        if cks.is_empty() {
            bail!("cannot average zero checkpoints");
        }
        let mut acc = cks[0].clone();
        for ck in &cks[1..] {
            acc.axpy(1.0, ck)?;
        }
        Ok(acc.scale(1.0 / cks.len() as f32))
    }

    /// Concatenate all tensors (name order) into one flat vector,
    /// zero-padded to a multiple of `block` — matches the Python
    /// `flatten_params` contract used by the merged-forward artifacts.
    pub fn flatten_padded(&self, block: usize) -> Vec<f32> {
        let n = self.numel();
        let padded = n.div_ceil(block) * block;
        let mut flat = Vec::with_capacity(padded);
        for t in self.tensors.values() {
            flat.extend_from_slice(t.data());
        }
        flat.resize(padded, 0.0);
        flat
    }

    /// Rebuild a checkpoint from a flat vector using `self` as the shape
    /// template (inverse of [`flatten_padded`]).
    pub fn unflatten_like(&self, flat: &[f32]) -> Result<Checkpoint> {
        let mut out = Checkpoint::new();
        let mut off = 0;
        for (name, t) in &self.tensors {
            let n = t.numel();
            if off + n > flat.len() {
                bail!("flat vector too short for template");
            }
            out.insert(
                name,
                Tensor::new(t.shape().to_vec(), flat[off..off + n].to_vec())?,
            );
            off += n;
        }
        Ok(out)
    }

    /// L2 distance between two checkpoints (used for quantization-error
    /// measurements, Fig. 4).
    pub fn l2_dist(&self, other: &Checkpoint) -> Result<f64> {
        self.check_compatible(other)?;
        let mut acc = 0.0f64;
        for (name, t) in &self.tensors {
            let d = crate::util::stats::l2_dist(t.data(), other.get(name)?.data());
            acc += d * d;
        }
        Ok(acc.sqrt())
    }

    /// Global (min, max) across all tensors — the "weight range" of Fig. 3.
    pub fn weight_range(&self) -> (f32, f32) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for t in self.tensors.values() {
            let (l, h) = t.min_max();
            lo = lo.min(l);
            hi = hi.max(h);
        }
        (lo, hi)
    }

    /// Save to disk via the binary container format.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        store::save_checkpoint(self, path.as_ref())
    }

    /// Load from disk.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Checkpoint> {
        store::load_checkpoint(path.as_ref())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn ck(seed: u64) -> Checkpoint {
        let mut rng = Rng::new(seed);
        let mut c = Checkpoint::new();
        c.insert("b/w", Tensor::randn(&[4, 3], 1.0, &mut rng));
        c.insert("a/w", Tensor::randn(&[5], 1.0, &mut rng));
        c
    }

    #[test]
    fn ordering_is_lexicographic() {
        let c = ck(0);
        let names: Vec<&str> = c.names().collect();
        assert_eq!(names, vec!["a/w", "b/w"]);
    }

    #[test]
    fn sub_add_roundtrip() {
        let a = ck(1);
        let b = ck(2);
        let tau = a.sub(&b).unwrap();
        let back = tau.add(&b).unwrap();
        for (name, t) in a.iter() {
            for (x, y) in t.data().iter().zip(back.get(name).unwrap().data()) {
                assert!((x - y).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn average_of_identical_is_identity() {
        let a = ck(3);
        let avg = Checkpoint::average(&[&a, &a, &a]).unwrap();
        for (name, t) in a.iter() {
            for (x, y) in t.data().iter().zip(avg.get(name).unwrap().data()) {
                assert!((x - y).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn flatten_unflatten_roundtrip() {
        let a = ck(4);
        let flat = a.flatten_padded(8);
        assert_eq!(flat.len() % 8, 0);
        assert!(flat.len() >= a.numel());
        let back = a.unflatten_like(&flat).unwrap();
        assert_eq!(a, back);
    }

    #[test]
    fn incompatible_checkpoints_error() {
        let a = ck(5);
        let mut b = ck(6);
        b.insert("extra", Tensor::zeros(&[1]));
        assert!(a.sub(&b).is_err());
        let mut c = Checkpoint::new();
        c.insert("a/w", Tensor::zeros(&[5]));
        c.insert("b/w", Tensor::zeros(&[4, 2])); // wrong shape
        assert!(a.sub(&c).is_err());
    }

    #[test]
    fn weight_range_spans_tensors() {
        let mut c = Checkpoint::new();
        c.insert("x", Tensor::from_vec(vec![-2.0, 0.5]));
        c.insert("y", Tensor::from_vec(vec![3.0]));
        assert_eq!(c.weight_range(), (-2.0, 3.0));
    }
}
