//! Binary checkpoint container + on-disk store.
//!
//! Format (`TVQC` v1, little-endian):
//! ```text
//!   magic  u32  = 0x43515654 ("TVQC")
//!   version u32 = 1
//!   count  u32  = number of tensors
//!   per tensor:
//!     name_len u32, name bytes (UTF-8)
//!     ndim u32, dims u64 * ndim
//!     f32 data (numel * 4 bytes)
//!   crc32  u32  over everything before it
//! ```
//! The CRC detects truncation/corruption of cached model zoos.

use std::fs;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::Checkpoint;
use crate::tensor::Tensor;

const MAGIC: u32 = 0x4351_5654; // "TVQC"
const VERSION: u32 = 1;

use crate::util::crc32;

pub(super) fn save_checkpoint(ck: &Checkpoint, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let mut buf: Vec<u8> = Vec::with_capacity(ck.fp32_bytes() + 1024);
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(ck.len() as u32).to_le_bytes());
    for (name, t) in ck.iter() {
        let nb = name.as_bytes();
        buf.extend_from_slice(&(nb.len() as u32).to_le_bytes());
        buf.extend_from_slice(nb);
        buf.extend_from_slice(&(t.shape().len() as u32).to_le_bytes());
        for &d in t.shape() {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for &v in t.data() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    let tmp = path.with_extension("tmp");
    {
        let mut f = fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&buf)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)?;
    Ok(())
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("checkpoint file truncated at byte {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

pub(super) fn load_checkpoint(path: &Path) -> Result<Checkpoint> {
    let mut bytes = Vec::new();
    fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_to_end(&mut bytes)?;
    // Validate the header (magic + version) before anything else so a
    // wrong-format or future-version file gets a precise diagnostic
    // instead of a downstream CRC/parse failure.
    if bytes.len() < 16 {
        bail!(
            "truncated TVQC header in {}: {} bytes, need at least 16 \
             (magic + version + count + crc)",
            path.display(),
            bytes.len()
        );
    }
    let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
    if magic != MAGIC {
        bail!(
            "not a TVQC checkpoint: {} (magic {magic:#010x}, expected {MAGIC:#010x})",
            path.display()
        );
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version != VERSION {
        bail!(
            "unsupported TVQC version {version} in {} (this build reads v{VERSION}; \
             packed registries use the separate QTVC v2 format — see tvq::registry)",
            path.display()
        );
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
    let want = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    let got = crc32(body);
    if want != got {
        bail!(
            "checkpoint CRC mismatch in {} (corrupt or truncated cache? \
             delete and regenerate)",
            path.display()
        );
    }
    // Skip the 8 header bytes (magic + version) validated above.
    let mut r = Reader { buf: body, pos: 8 };
    let count = r.u32()? as usize;
    let mut ck = Checkpoint::new();
    for _ in 0..count {
        let name_len = r.u32()? as usize;
        let name = std::str::from_utf8(r.take(name_len)?)?.to_string();
        let ndim = r.u32()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.u64()? as usize);
        }
        let numel: usize = shape.iter().product();
        let raw = r.take(numel * 4)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        ck.insert(&name, Tensor::new(shape, data)?);
    }
    Ok(ck)
}

/// A directory of named checkpoints (the "model zoo" cache).
pub struct CheckpointStore {
    root: PathBuf,
}

impl CheckpointStore {
    pub fn new<P: AsRef<Path>>(root: P) -> Self {
        Self { root: root.as_ref().to_path_buf() }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn path(&self, name: &str) -> PathBuf {
        self.root.join(format!("{name}.ckpt"))
    }

    pub fn exists(&self, name: &str) -> bool {
        self.path(name).exists()
    }

    pub fn save(&self, name: &str, ck: &Checkpoint) -> Result<()> {
        ck.save(self.path(name))
    }

    pub fn load(&self, name: &str) -> Result<Checkpoint> {
        Checkpoint::load(self.path(name))
    }

    /// Load if cached, otherwise build via `f` and cache the result.
    pub fn load_or_build<F>(&self, name: &str, f: F) -> Result<Checkpoint>
    where
        F: FnOnce() -> Result<Checkpoint>,
    {
        if self.exists(name) {
            match self.load(name) {
                Ok(ck) => return Ok(ck),
                Err(e) => {
                    // Corrupt cache entry: rebuild.
                    eprintln!("warn: rebuilding {name}: {e}");
                }
            }
        }
        let ck = f()?;
        self.save(name, &ck)?;
        Ok(ck)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample() -> Checkpoint {
        let mut rng = Rng::new(9);
        let mut ck = Checkpoint::new();
        ck.insert("layer/w", Tensor::randn(&[3, 4], 0.5, &mut rng));
        ck.insert("layer/b", Tensor::randn(&[4], 0.1, &mut rng));
        ck.insert("emptyish", Tensor::zeros(&[1]));
        ck
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("tvq_store_test_rt");
        let path = dir.join("x.ckpt");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_detected() {
        let dir = std::env::temp_dir().join("tvq_store_test_crc");
        let path = dir.join("x.ckpt");
        sample().save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_load_or_build_caches() {
        let dir = std::env::temp_dir().join("tvq_store_test_lob");
        std::fs::remove_dir_all(&dir).ok();
        let store = CheckpointStore::new(&dir);
        let mut builds = 0;
        let a = store
            .load_or_build("m", || {
                builds += 1;
                Ok(sample())
            })
            .unwrap();
        let b = store
            .load_or_build("m", || {
                builds += 1;
                Ok(sample())
            })
            .unwrap();
        assert_eq!(builds, 1);
        assert_eq!(a, b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crc32_known_vector() {
        // CRC-32 of "123456789" is 0xCBF43926.
        assert_eq!(super::crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn unknown_version_rejected_with_clear_error() {
        let dir = std::env::temp_dir().join("tvq_store_test_ver");
        let path = dir.join("x.ckpt");
        sample().save(&path).unwrap();
        // Bump the version field and re-seal the CRC so only the version
        // check can fire (the file is otherwise intact).
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
        let body_len = bytes.len() - 4;
        let crc = super::crc32(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&crc.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(
            err.contains("unsupported TVQC version 99"),
            "unexpected error: {err}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_header_rejected_with_clear_error() {
        let dir = std::env::temp_dir().join("tvq_store_test_trunc");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.ckpt");
        // 8 bytes: magic + version only — header cut short.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&super::MAGIC.to_le_bytes());
        bytes.extend_from_slice(&super::VERSION.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("truncated TVQC header"), "unexpected error: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_magic_rejected() {
        let dir = std::env::temp_dir().join("tvq_store_test_magic");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.ckpt");
        std::fs::write(&path, [0u8; 32]).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("not a TVQC checkpoint"), "unexpected error: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
