//! Binary checkpoint container + on-disk store.
//!
//! Format (`TVQC` v1, little-endian):
//! ```text
//!   magic  u32  = 0x43515654 ("TVQC")
//!   version u32 = 1
//!   count  u32  = number of tensors
//!   per tensor:
//!     name_len u32, name bytes (UTF-8)
//!     ndim u32, dims u64 * ndim
//!     f32 data (numel * 4 bytes)
//!   crc32  u32  over everything before it
//! ```
//! The CRC detects truncation/corruption of cached model zoos.

use std::fs;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::Checkpoint;
use crate::tensor::Tensor;

const MAGIC: u32 = 0x4351_5654; // "TVQC"
const VERSION: u32 = 1;

fn crc32(bytes: &[u8]) -> u32 {
    // CRC-32 (IEEE 802.3), table-driven.
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

pub(super) fn save_checkpoint(ck: &Checkpoint, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let mut buf: Vec<u8> = Vec::with_capacity(ck.fp32_bytes() + 1024);
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(ck.len() as u32).to_le_bytes());
    for (name, t) in ck.iter() {
        let nb = name.as_bytes();
        buf.extend_from_slice(&(nb.len() as u32).to_le_bytes());
        buf.extend_from_slice(nb);
        buf.extend_from_slice(&(t.shape().len() as u32).to_le_bytes());
        for &d in t.shape() {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for &v in t.data() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    let tmp = path.with_extension("tmp");
    {
        let mut f = fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&buf)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)?;
    Ok(())
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("checkpoint file truncated at byte {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

pub(super) fn load_checkpoint(path: &Path) -> Result<Checkpoint> {
    let mut bytes = Vec::new();
    fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_to_end(&mut bytes)?;
    if bytes.len() < 16 {
        bail!("checkpoint file too small: {}", path.display());
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
    let want = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    let got = crc32(body);
    if want != got {
        bail!(
            "checkpoint CRC mismatch in {} (corrupt cache? delete and regenerate)",
            path.display()
        );
    }
    let mut r = Reader { buf: body, pos: 0 };
    if r.u32()? != MAGIC {
        bail!("not a TVQC checkpoint: {}", path.display());
    }
    let version = r.u32()?;
    if version != VERSION {
        bail!("unsupported TVQC version {version}");
    }
    let count = r.u32()? as usize;
    let mut ck = Checkpoint::new();
    for _ in 0..count {
        let name_len = r.u32()? as usize;
        let name = std::str::from_utf8(r.take(name_len)?)?.to_string();
        let ndim = r.u32()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.u64()? as usize);
        }
        let numel: usize = shape.iter().product();
        let raw = r.take(numel * 4)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        ck.insert(&name, Tensor::new(shape, data)?);
    }
    Ok(ck)
}

/// A directory of named checkpoints (the "model zoo" cache).
pub struct CheckpointStore {
    root: PathBuf,
}

impl CheckpointStore {
    pub fn new<P: AsRef<Path>>(root: P) -> Self {
        Self { root: root.as_ref().to_path_buf() }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn path(&self, name: &str) -> PathBuf {
        self.root.join(format!("{name}.ckpt"))
    }

    pub fn exists(&self, name: &str) -> bool {
        self.path(name).exists()
    }

    pub fn save(&self, name: &str, ck: &Checkpoint) -> Result<()> {
        ck.save(self.path(name))
    }

    pub fn load(&self, name: &str) -> Result<Checkpoint> {
        Checkpoint::load(self.path(name))
    }

    /// Load if cached, otherwise build via `f` and cache the result.
    pub fn load_or_build<F>(&self, name: &str, f: F) -> Result<Checkpoint>
    where
        F: FnOnce() -> Result<Checkpoint>,
    {
        if self.exists(name) {
            match self.load(name) {
                Ok(ck) => return Ok(ck),
                Err(e) => {
                    // Corrupt cache entry: rebuild.
                    eprintln!("warn: rebuilding {name}: {e}");
                }
            }
        }
        let ck = f()?;
        self.save(name, &ck)?;
        Ok(ck)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample() -> Checkpoint {
        let mut rng = Rng::new(9);
        let mut ck = Checkpoint::new();
        ck.insert("layer/w", Tensor::randn(&[3, 4], 0.5, &mut rng));
        ck.insert("layer/b", Tensor::randn(&[4], 0.1, &mut rng));
        ck.insert("emptyish", Tensor::zeros(&[1]));
        ck
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("tvq_store_test_rt");
        let path = dir.join("x.ckpt");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_detected() {
        let dir = std::env::temp_dir().join("tvq_store_test_crc");
        let path = dir.join("x.ckpt");
        sample().save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_load_or_build_caches() {
        let dir = std::env::temp_dir().join("tvq_store_test_lob");
        std::fs::remove_dir_all(&dir).ok();
        let store = CheckpointStore::new(&dir);
        let mut builds = 0;
        let a = store
            .load_or_build("m", || {
                builds += 1;
                Ok(sample())
            })
            .unwrap();
        let b = store
            .load_or_build("m", || {
                builds += 1;
                Ok(sample())
            })
            .unwrap();
        assert_eq!(builds, 1);
        assert_eq!(a, b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crc32_known_vector() {
        // CRC-32 of "123456789" is 0xCBF43926.
        assert_eq!(super::crc32(b"123456789"), 0xCBF4_3926);
    }
}
