//! [`PackPlan`] — the serializable output of the budget-aware pack
//! planner, and the exact byte arithmetic the solver optimizes against.
//!
//! A plan assigns every tensor (layer) of the task suite one quantization
//! **arm**: independent per-task group quantization ([`Arm::Tvq`]), a
//! shared group-quantized base plus per-task low-bit offsets
//! ([`Arm::Rtvq`], the paper's Section 4.3 decomposition applied per
//! layer), or one of the sparse families — DARE drop-and-rescale
//! ([`Arm::Dare`], arXiv 2402.09997) and TALL-mask task localization
//! ([`Arm::Tall`], arXiv 2405.07813) — where masked-out weights cost 0
//! bits and only the survivors carry quantized codes — or the 1-bit
//! binary switch ([`Arm::OneBit`], after 1bit-Merging / Binary Task
//! Switch), where a task's slice collapses to a sign bitmap plus scales.
//! The registry writer compiles a plan into kind-2 `GroupQuantized` /
//! kind-4 `SparseGroupQuantized` / kind-5 `BinarySwitch` sections and
//! embeds the plan itself as the kind-3 metadata section so readers can
//! map sections back to `(task, tensor)` slots and reconstruct tensor
//! shapes.
//!
//! The normative byte-level layout of the plan body (wire v1 dense-only,
//! v2 adds the sparse arm kinds, v3 the binary arm kind) and of every
//! section kind lives in
//! `docs/WIRE_FORMAT.md`; this module implements it.  One property the
//! solver depends on: the plan body size is a function of names, shapes
//! and counts only — never of which arms were chosen — so the plan
//! section is accounted exactly *before* solving.
//!
//! # Exact cost model
//!
//! Every candidate arm is priced in **real file bytes**, not ideal bits:
//! packed codes + per-group scale/zp pairs + bitmasks (sparse arms) + the
//! offset-table rows of the sections the arm creates (and the base
//! section, for RTVQ arms).  [`PackPlan::planned_file_bytes`] is
//! therefore a byte-exact prediction of the registry file the writer
//! emits; `write_planned_registry` enforces the equality.

use std::collections::HashSet;

use anyhow::{bail, Result};

use crate::registry::container::{Cursor, PayloadKind};

/// Name of the kind-3 plan-metadata section in the registry index.
pub const PLAN_SECTION_NAME: &str = "__plan__";
/// Wire version of dense-arms-only plan bodies.
pub const PLAN_WIRE_VERSION: u8 = 1;
/// Wire version of plan bodies that use sparse (DARE / TALL) arms; the
/// layout is byte-identical to v1, v2 merely admits arm kinds 2 and 3.
/// Readers accept both.
pub const PLAN_WIRE_VERSION_SPARSE: u8 = 2;
/// Wire version of plan bodies that use the 1-bit binary arm; again
/// byte-identical layout, v3 merely admits arm kind 4 (and, like v2, the
/// sparse kinds).
pub const PLAN_WIRE_VERSION_BINARY: u8 = 3;
/// Shape-sanity cap shared with the checkpoint payload decoder.
const MAX_NDIM: usize = 16;

/// One quantization arm for a tensor, applied across every task.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arm {
    /// Each task's slice quantized independently at `bits`.
    Tvq { bits: u8 },
    /// One shared base at `base_bits` (the task-mean slice, stored once)
    /// plus per-task offsets at `offset_bits`, with error correction:
    /// offsets are computed against the *dequantized* base.
    Rtvq { base_bits: u8, offset_bits: u8 },
    /// DARE sparsify-then-quantize: a deterministic pseudo-random
    /// `drop_pct`% of each task's entries are dropped, survivors are
    /// rescaled by `dense/survivors` (the unbiased 1/(1-p)) and group-
    /// quantized at `bits`.  Stored as a kind-4 sparse section per task.
    Dare { drop_pct: u8, bits: u8 },
    /// TALL-mask-localized allocation: per task, the `keep_pct`% of
    /// entries with the highest task-localization score
    /// |tau_t| / |tau_mtl - tau_t| (computed from the multi-task vector)
    /// survive and are group-quantized at `bits`; the rest are stored at
    /// 0 bits.  Stored as a kind-4 sparse section per task.
    Tall { keep_pct: u8, bits: u8 },
    /// 1-bit binary switch (1bit-Merging, arXiv 2502.10743; Binary Task
    /// Switch, arXiv 2412.00054): each task's slice collapses to a sign
    /// bitmap plus mean-|x| scales — per group, or one per tensor when
    /// `per_tensor_scale`.  Stored as a kind-5 binary section per task;
    /// the cheapest arm and the payload the dynamic-merge router flips
    /// per request.
    OneBit { per_tensor_scale: bool },
}

impl Arm {
    pub fn label(&self) -> String {
        match self {
            Arm::Tvq { bits } => format!("TVQ-INT{bits}"),
            Arm::Rtvq { base_bits, offset_bits } => {
                format!("RTVQ-B{base_bits}O{offset_bits}")
            }
            Arm::Dare { drop_pct, bits } => format!("DARE-D{drop_pct}B{bits}"),
            Arm::Tall { keep_pct, bits } => format!("TALL-K{keep_pct}B{bits}"),
            Arm::OneBit { per_tensor_scale: true } => "1BIT-T".to_string(),
            Arm::OneBit { per_tensor_scale: false } => "1BIT-G".to_string(),
        }
    }

    /// True for the sparse families (kind-4 sections, plan wire v2).
    pub fn is_sparse(&self) -> bool {
        matches!(self, Arm::Dare { .. } | Arm::Tall { .. })
    }

    /// True for the 1-bit binary switch (kind-5 sections, plan wire v3).
    pub fn is_binary(&self) -> bool {
        matches!(self, Arm::OneBit { .. })
    }

    /// Exact survivor count per task section for a tensor of `padded`
    /// flat elements — pure integer arithmetic shared by the probe, the
    /// cost model and the writer, so all three agree to the byte.
    /// `None` for dense arms.
    pub fn survivors(&self, padded: usize) -> Option<usize> {
        match *self {
            Arm::Dare { drop_pct, .. } => {
                Some(padded - padded * drop_pct as usize / 100)
            }
            Arm::Tall { keep_pct, .. } => {
                Some((padded * keep_pct as usize / 100).max(1))
            }
            Arm::Tvq { .. } | Arm::Rtvq { .. } | Arm::OneBit { .. } => None,
        }
    }

    /// The group width a binary arm's scales cover for a tensor of
    /// `padded` flat elements and plan group `group`: the tensor's group,
    /// or the whole tensor for a single per-tensor scale.  `None` for
    /// non-binary arms.
    pub fn binary_group(&self, padded: usize, group: usize) -> Option<usize> {
        match *self {
            Arm::OneBit { per_tensor_scale } => {
                Some(if per_tensor_scale { padded } else { group })
            }
            _ => None,
        }
    }

    /// Survivor rescale factor: DARE's unbiased `dense/kept`; 1.0 for
    /// TALL masks (localization keeps values as-is).
    pub fn rescale(&self, padded: usize, survivors: usize) -> f32 {
        match self {
            Arm::Dare { .. } => padded as f32 / survivors as f32,
            _ => 1.0,
        }
    }

    fn check(&self) -> Result<()> {
        let ok = |b: u8| (1..=8).contains(&b);
        let pct = |p: u8| (1..=99).contains(&p);
        match *self {
            Arm::Tvq { bits } if ok(bits) => Ok(()),
            Arm::Rtvq { base_bits, offset_bits } if ok(base_bits) && ok(offset_bits) => Ok(()),
            Arm::Dare { drop_pct, bits } if ok(bits) && pct(drop_pct) => Ok(()),
            Arm::Tall { keep_pct, bits } if ok(bits) && pct(keep_pct) => Ok(()),
            Arm::OneBit { .. } => Ok(()),
            other => bail!(
                "pack plan arm {other:?} has bits outside 1..=8 or percentage \
                 outside 1..=99"
            ),
        }
    }
}

/// One tensor (layer) the plan covers: its shape template and the group
/// width its flat data is quantized at.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanTensor {
    pub name: String,
    pub shape: Vec<usize>,
    /// Per-group quantization width; `1 <= group <= numel`, chosen as
    /// `min(config.group, numel)` so tiny tensors never pad past their
    /// own length.
    pub group: usize,
}

impl PlanTensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Flat length after zero-padding up to a multiple of `group`.
    pub fn padded(&self) -> usize {
        self.numel().div_ceil(self.group) * self.group
    }

    pub fn n_groups(&self) -> usize {
        self.padded() / self.group
    }
}

/// The arm chosen for one tensor, with the probe's measurements.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Assignment {
    pub arm: Arm,
    /// Exact bytes this arm adds to the registry file (sections + rows).
    pub cost_bytes: u64,
    /// Probed reconstruction error: sum over tasks of squared L2 error.
    pub error: f64,
}

/// Where one expected payload section slots into the plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SectionRole {
    /// Shared base for tensor `tensor` (RTVQ arms only).
    Base { tensor: usize },
    /// Task `task`'s payload for tensor `tensor`.
    Task { task: usize, tensor: usize },
}

/// What a payload section must decode to, per the plan's arm for its
/// slot — returned by [`PackPlan::section_spec`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SectionSpec {
    /// A kind-2 [`GroupQuantized`](crate::quant::GroupQuantized) payload
    /// of `len` flat elements.
    Dense { bits: u8, group: usize, len: usize },
    /// A kind-4 [`SparseGroupQuantized`](crate::quant::SparseGroupQuantized)
    /// payload: `dense_len` logical elements, exactly `survivors` of them
    /// stored at `bits`.
    Sparse { bits: u8, group: usize, dense_len: usize, survivors: usize },
    /// A kind-5 [`BinarySwitch`](crate::quant::BinarySwitch) payload of
    /// `len` flat elements with one scale per `group` (== `len` for a
    /// per-tensor scale).
    Binary { group: usize, len: usize },
}

/// A solved bit-allocation: one [`Assignment`] per tensor, under
/// `budget_bytes`, for the named task suite.
#[derive(Clone, Debug, PartialEq)]
pub struct PackPlan {
    pub budget_bytes: u64,
    pub task_names: Vec<String>,
    pub tensors: Vec<PlanTensor>,
    /// One entry per tensor, same order as `tensors`.
    pub assignments: Vec<Assignment>,
}

/// Exact encoded size of one kind-2 group section body:
/// `bits u8 + group u64 + n_groups u64 + (scale,zp) f32 pairs + codes`.
pub fn group_payload_bytes(padded: usize, bits: u8, group: usize) -> u64 {
    debug_assert_eq!(padded % group, 0);
    (17 + (padded / group) * 8 + (padded * bits as usize).div_ceil(8)) as u64
}

/// Exact encoded size of one kind-4 sparse section body: `dense_len u64
/// + n_survivors u64 + bitmask` followed by the embedded group payload
/// of the survivors padded up to a multiple of the group width.
pub fn sparse_payload_bytes(padded: usize, survivors: usize, bits: u8, group: usize) -> u64 {
    let k_pad = survivors.div_ceil(group) * group;
    16 + padded.div_ceil(8) as u64 + group_payload_bytes(k_pad, bits, group)
}

/// Exact encoded size of one kind-5 binary section body:
/// `group u64 + n_groups u64 + scales f32 * n_groups + sign bitmap`.
pub fn binary_payload_bytes(padded: usize, group: usize) -> u64 {
    debug_assert_eq!(padded % group, 0);
    (16 + (padded / group) * 4 + padded.div_ceil(8)) as u64
}

/// Exact offset-table row size for a section named `name`:
/// `name_len u32 + name + kind u8 + offset u64 + length u64 + crc u32`.
pub fn index_row_bytes(name: &str) -> u64 {
    (4 + name.len() + 1 + 8 + 8 + 4) as u64
}

/// Registry section name for task `task_name`'s slice of `tensor_name`.
pub fn task_section_name(task_name: &str, tensor_name: &str) -> String {
    format!("{task_name}/{tensor_name}")
}

/// Registry section name for the shared base of `tensor_name`.
pub fn base_section_name(tensor_name: &str) -> String {
    format!("__base__/{tensor_name}")
}

/// Exact bytes arm `arm` adds to the file for `tensor` across
/// `task_names`: section bodies plus their offset-table rows (plus the
/// base section and its row for RTVQ arms).  Sparse arms have a fixed,
/// data-independent survivor count ([`Arm::survivors`]), which is what
/// keeps this a pure function the solver can price before quantizing.
pub fn arm_cost_bytes(task_names: &[String], tensor: &PlanTensor, arm: Arm) -> u64 {
    let padded = tensor.padded();
    let rows = || -> u64 {
        task_names
            .iter()
            .map(|t| index_row_bytes(&task_section_name(t, &tensor.name)))
            .sum()
    };
    let per_task = |bits: u8| -> u64 {
        task_names.len() as u64 * group_payload_bytes(padded, bits, tensor.group) + rows()
    };
    match arm {
        Arm::Tvq { bits } => per_task(bits),
        Arm::Rtvq { base_bits, offset_bits } => {
            group_payload_bytes(padded, base_bits, tensor.group)
                + index_row_bytes(&base_section_name(&tensor.name))
                + per_task(offset_bits)
        }
        Arm::Dare { bits, .. } | Arm::Tall { bits, .. } => {
            let k = arm.survivors(padded).expect("sparse arm");
            task_names.len() as u64 * sparse_payload_bytes(padded, k, bits, tensor.group)
                + rows()
        }
        Arm::OneBit { .. } => {
            let g = arm.binary_group(padded, tensor.group).expect("binary arm");
            task_names.len() as u64 * binary_payload_bytes(padded, g) + rows()
        }
    }
}

/// Exact size of the encoded plan body — depends only on names, shapes
/// and counts, never on the chosen arms.
pub fn plan_meta_bytes(task_names: &[String], tensors: &[PlanTensor]) -> u64 {
    let tasks: usize = task_names.iter().map(|t| 4 + t.len()).sum();
    let tensors_b: usize = tensors
        .iter()
        .map(|t| 4 + t.name.len() + 4 + 8 * t.shape.len() + 8 + 1 + 1 + 1 + 8 + 8)
        .sum();
    (1 + 8 + 4 + tasks + 4 + tensors_b) as u64
}

/// Registry bytes independent of the allocation: header, plan section
/// (body + row), and the trailing index CRC.
pub fn fixed_file_bytes(task_names: &[String], tensors: &[PlanTensor]) -> u64 {
    let header = (4 + 4 + 4 + crate::registry::container::PLANNED_LABEL.len() + 4) as u64;
    header
        + index_row_bytes(PLAN_SECTION_NAME)
        + plan_meta_bytes(task_names, tensors)
        + 4
}

impl PackPlan {
    pub fn n_tasks(&self) -> usize {
        self.task_names.len()
    }

    pub fn n_tensors(&self) -> usize {
        self.tensors.len()
    }

    /// Parameters per task payload (unpadded).
    pub fn params_per_task(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// Byte-exact prediction of the registry file this plan compiles to,
    /// recomputed from the arms (not the stored per-arm costs).
    pub fn planned_file_bytes(&self) -> u64 {
        fixed_file_bytes(&self.task_names, &self.tensors)
            + self
                .tensors
                .iter()
                .zip(&self.assignments)
                .map(|(t, a)| arm_cost_bytes(&self.task_names, t, a.arm))
                .sum::<u64>()
    }

    /// Metadata-free code bytes — the planned analog of
    /// [`StorageReport::ideal`](crate::quant::StorageReport::ideal).  For
    /// sparse arms the bitmask is payload (1 bit per dense element), the
    /// per-group affine params are metadata.
    pub fn ideal_code_bytes(&self) -> u64 {
        let n_tasks = self.n_tasks();
        self.tensors
            .iter()
            .zip(&self.assignments)
            .map(|(t, a)| {
                let padded = t.padded();
                let codes = |bits: u8| ((padded * bits as usize).div_ceil(8)) as u64;
                match a.arm {
                    Arm::Tvq { bits } => n_tasks as u64 * codes(bits),
                    Arm::Rtvq { base_bits, offset_bits } => {
                        codes(base_bits) + n_tasks as u64 * codes(offset_bits)
                    }
                    Arm::Dare { bits, .. } | Arm::Tall { bits, .. } => {
                        let k = a.arm.survivors(padded).expect("sparse arm");
                        n_tasks as u64
                            * (padded.div_ceil(8) + (k * bits as usize).div_ceil(8)) as u64
                    }
                    // The sign bitmap is the payload; scales are metadata.
                    Arm::OneBit { .. } => n_tasks as u64 * padded.div_ceil(8) as u64,
                }
            })
            .sum()
    }

    /// True when any tensor uses a sparse (DARE / TALL) arm — such plans
    /// serialize at wire v2+ and their registries carry kind-4 sections
    /// (QTVC v4, or v5 alongside binary arms).
    pub fn has_sparse_arms(&self) -> bool {
        self.assignments.iter().any(|a| a.arm.is_sparse())
    }

    /// True when any tensor uses the 1-bit binary arm — such plans
    /// serialize at wire v3 and their registries carry kind-5 sections
    /// (QTVC v5).
    pub fn has_onebit_arms(&self) -> bool {
        self.assignments.iter().any(|a| a.arm.is_binary())
    }

    /// Total probed reconstruction error (sum of squared L2 across all
    /// tasks and tensors).
    pub fn total_error(&self) -> f64 {
        self.assignments.iter().map(|a| a.error).sum()
    }

    /// Every payload section this plan expects (kind-2 dense / kind-4
    /// sparse), with its role — the registry open path validates the
    /// file's section set and per-row kinds against exactly this.
    pub fn expected_sections(&self) -> Vec<(String, SectionRole)> {
        let mut out = Vec::new();
        for (l, (tensor, a)) in self.tensors.iter().zip(&self.assignments).enumerate() {
            if matches!(a.arm, Arm::Rtvq { .. }) {
                out.push((base_section_name(&tensor.name), SectionRole::Base { tensor: l }));
            }
        }
        for (t, task) in self.task_names.iter().enumerate() {
            for (l, tensor) in self.tensors.iter().enumerate() {
                out.push((
                    task_section_name(task, &tensor.name),
                    SectionRole::Task { task: t, tensor: l },
                ));
            }
        }
        out
    }

    /// The exact payload a section must decode to under this plan, by
    /// role — the lazy loader cross-checks decoded geometry against it.
    pub fn section_spec(&self, role: SectionRole) -> SectionSpec {
        let (l, arm) = match role {
            SectionRole::Base { tensor } => (tensor, self.assignments[tensor].arm),
            SectionRole::Task { tensor, .. } => (tensor, self.assignments[tensor].arm),
        };
        let t = &self.tensors[l];
        let padded = t.padded();
        let dense = |bits| SectionSpec::Dense { bits, group: t.group, len: padded };
        match (role, arm) {
            (SectionRole::Base { .. }, Arm::Rtvq { base_bits, .. }) => dense(base_bits),
            (SectionRole::Base { .. }, other) => {
                unreachable!("base role on a non-RTVQ arm {other:?}")
            }
            (_, Arm::Tvq { bits }) => dense(bits),
            (_, Arm::Rtvq { offset_bits, .. }) => dense(offset_bits),
            (_, arm @ (Arm::Dare { bits, .. } | Arm::Tall { bits, .. })) => {
                SectionSpec::Sparse {
                    bits,
                    group: t.group,
                    dense_len: padded,
                    survivors: arm.survivors(padded).expect("sparse arm"),
                }
            }
            (_, arm @ Arm::OneBit { .. }) => SectionSpec::Binary {
                group: arm.binary_group(padded, t.group).expect("binary arm"),
                len: padded,
            },
        }
    }

    /// The index-entry kind a section of `role` must carry: kind-2 group
    /// payloads for dense arms and bases, kind-4 sparse payloads for
    /// DARE / TALL task sections, kind-5 binary payloads for OneBit task
    /// sections.  The open path validates the file's offset table against
    /// this before any payload is read.
    pub fn expected_section_kind(&self, role: SectionRole) -> PayloadKind {
        match self.section_spec(role) {
            SectionSpec::Dense { .. } => PayloadKind::Group,
            SectionSpec::Sparse { .. } => PayloadKind::SparseGroup,
            SectionSpec::Binary { .. } => PayloadKind::BinarySwitch,
        }
    }

    /// Structural validation: counts, name rules, arm ranges, and stored
    /// per-arm costs matching the byte arithmetic exactly.
    pub fn validate(&self) -> Result<()> {
        if self.task_names.is_empty() {
            bail!("pack plan covers zero tasks");
        }
        if self.tensors.is_empty() {
            bail!("pack plan covers zero tensors");
        }
        if self.assignments.len() != self.tensors.len() {
            bail!(
                "pack plan has {} assignments for {} tensors",
                self.assignments.len(),
                self.tensors.len()
            );
        }
        let mut seen = HashSet::new();
        for t in &self.task_names {
            if t.is_empty() || t.contains('/') || t.starts_with("__") {
                bail!("pack plan task name {t:?} (must be non-empty, no '/', no '__' prefix)");
            }
            if !seen.insert(t.as_str()) {
                bail!("pack plan has duplicate task name {t:?}");
            }
        }
        let mut seen = HashSet::new();
        for (tensor, a) in self.tensors.iter().zip(&self.assignments) {
            if tensor.name.is_empty() {
                bail!("pack plan tensor name must be non-empty");
            }
            if !seen.insert(tensor.name.as_str()) {
                bail!("pack plan has duplicate tensor name {:?}", tensor.name);
            }
            let numel = tensor.numel();
            if numel == 0 {
                bail!("pack plan tensor {:?} has zero elements", tensor.name);
            }
            if tensor.group == 0 || tensor.group > numel {
                bail!(
                    "pack plan tensor {:?}: group {} outside 1..={numel}",
                    tensor.name,
                    tensor.group
                );
            }
            a.arm.check()?;
            if !a.error.is_finite() || a.error < 0.0 {
                bail!("pack plan tensor {:?}: non-finite error {}", tensor.name, a.error);
            }
            let want = arm_cost_bytes(&self.task_names, tensor, a.arm);
            if a.cost_bytes != want {
                bail!(
                    "pack plan tensor {:?}: stored cost {} != computed {want}",
                    tensor.name,
                    a.cost_bytes
                );
            }
        }
        Ok(())
    }

    /// Serialize to the kind-3 section body.  Dense-only plans stay at
    /// wire v1 so files written by older builds and this one are
    /// byte-identical; plans with sparse arms serialize at v2, plans with
    /// binary arms at v3.
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.push(if self.has_onebit_arms() {
            PLAN_WIRE_VERSION_BINARY
        } else if self.has_sparse_arms() {
            PLAN_WIRE_VERSION_SPARSE
        } else {
            PLAN_WIRE_VERSION
        });
        buf.extend_from_slice(&self.budget_bytes.to_le_bytes());
        buf.extend_from_slice(&(self.task_names.len() as u32).to_le_bytes());
        for t in &self.task_names {
            buf.extend_from_slice(&(t.len() as u32).to_le_bytes());
            buf.extend_from_slice(t.as_bytes());
        }
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (tensor, a) in self.tensors.iter().zip(&self.assignments) {
            buf.extend_from_slice(&(tensor.name.len() as u32).to_le_bytes());
            buf.extend_from_slice(tensor.name.as_bytes());
            buf.extend_from_slice(&(tensor.shape.len() as u32).to_le_bytes());
            for &d in &tensor.shape {
                buf.extend_from_slice(&(d as u64).to_le_bytes());
            }
            buf.extend_from_slice(&(tensor.group as u64).to_le_bytes());
            let (kind, b1, b2) = match a.arm {
                Arm::Tvq { bits } => (0u8, bits, 0u8),
                Arm::Rtvq { base_bits, offset_bits } => (1u8, base_bits, offset_bits),
                Arm::Dare { drop_pct, bits } => (2u8, bits, drop_pct),
                Arm::Tall { keep_pct, bits } => (3u8, bits, keep_pct),
                Arm::OneBit { per_tensor_scale } => (4u8, 1u8, per_tensor_scale as u8),
            };
            buf.push(kind);
            buf.push(b1);
            buf.push(b2);
            buf.extend_from_slice(&a.cost_bytes.to_le_bytes());
            buf.extend_from_slice(&a.error.to_le_bytes());
        }
        debug_assert_eq!(
            buf.len() as u64,
            plan_meta_bytes(&self.task_names, &self.tensors)
        );
        buf
    }

    /// Decode and fully validate a kind-3 section body (wire v1, v2 or
    /// v3).
    pub fn decode(buf: &[u8]) -> Result<PackPlan> {
        let mut c = Cursor::new(buf);
        let ver = c.u8()?;
        if ver != PLAN_WIRE_VERSION
            && ver != PLAN_WIRE_VERSION_SPARSE
            && ver != PLAN_WIRE_VERSION_BINARY
        {
            bail!(
                "pack plan wire version {ver} (this build reads \
                 v{PLAN_WIRE_VERSION}..=v{PLAN_WIRE_VERSION_BINARY})"
            );
        }
        let budget_bytes = c.u64()?;
        let task_cnt = c.u32()? as usize;
        // Untrusted counts: every name costs >= 4 bytes on the wire.
        if task_cnt > c.remaining() / 4 {
            bail!("pack plan claims {task_cnt} tasks in a {}-byte body", buf.len());
        }
        let mut task_names = Vec::with_capacity(task_cnt);
        for _ in 0..task_cnt {
            task_names.push(c.str()?);
        }
        let tensor_cnt = c.u32()? as usize;
        if tensor_cnt > c.remaining() / 4 {
            bail!("pack plan claims {tensor_cnt} tensors in a {}-byte body", buf.len());
        }
        let mut tensors = Vec::with_capacity(tensor_cnt);
        let mut assignments = Vec::with_capacity(tensor_cnt);
        for _ in 0..tensor_cnt {
            let name = c.str()?;
            let ndim = c.u32()? as usize;
            if ndim > MAX_NDIM {
                bail!("pack plan tensor {name:?}: implausible ndim {ndim}");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(c.u64()? as usize);
            }
            shape
                .iter()
                .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                .ok_or_else(|| anyhow::anyhow!("pack plan tensor {name:?}: shape overflow"))?;
            let group = c.u64()? as usize;
            let kind = c.u8()?;
            let b1 = c.u8()?;
            let b2 = c.u8()?;
            let arm = match kind {
                0 => {
                    if b2 != 0 {
                        bail!("pack plan tensor {name:?}: TVQ arm with offset bits {b2}");
                    }
                    Arm::Tvq { bits: b1 }
                }
                1 => Arm::Rtvq { base_bits: b1, offset_bits: b2 },
                2 | 3 if ver == PLAN_WIRE_VERSION => bail!(
                    "pack plan tensor {name:?}: sparse arm kind {kind} in a v1 \
                     plan body (sparse arms require wire v2)"
                ),
                2 => Arm::Dare { drop_pct: b2, bits: b1 },
                3 => Arm::Tall { keep_pct: b2, bits: b1 },
                4 if ver != PLAN_WIRE_VERSION_BINARY => bail!(
                    "pack plan tensor {name:?}: binary arm kind 4 in a v{ver} \
                     plan body (binary arms require wire v3)"
                ),
                4 => {
                    if b1 != 1 || b2 > 1 {
                        bail!(
                            "pack plan tensor {name:?}: binary arm with bits \
                             {b1} / scale flag {b2} (expected 1 / 0..=1)"
                        );
                    }
                    Arm::OneBit { per_tensor_scale: b2 == 1 }
                }
                other => bail!("pack plan tensor {name:?}: unknown arm kind {other}"),
            };
            let cost_bytes = c.u64()?;
            let error = c.f64()?;
            tensors.push(PlanTensor { name, shape, group });
            assignments.push(Assignment { arm, cost_bytes, error });
        }
        if !c.done() {
            bail!("pack plan body has trailing bytes");
        }
        let plan = PackPlan { budget_bytes, task_names, tensors, assignments };
        plan.validate()?;
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::GroupQuantized;
    use crate::registry::container::encode_group_payload;
    use crate::util::rng::Rng;

    fn sample_plan() -> PackPlan {
        let task_names = vec!["task00".to_string(), "task01".to_string()];
        let tensors = vec![
            PlanTensor { name: "blk00/w".into(), shape: vec![32, 16], group: 128 },
            PlanTensor { name: "head/b".into(), shape: vec![33], group: 33 },
        ];
        let arms = [Arm::Tvq { bits: 4 }, Arm::Rtvq { base_bits: 3, offset_bits: 2 }];
        let assignments = tensors
            .iter()
            .zip(arms)
            .map(|(t, arm)| Assignment {
                arm,
                cost_bytes: arm_cost_bytes(&task_names, t, arm),
                error: 0.25,
            })
            .collect();
        PackPlan { budget_bytes: 1 << 20, task_names, tensors, assignments }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let plan = sample_plan();
        plan.validate().unwrap();
        let wire = plan.encode();
        assert_eq!(
            wire.len() as u64,
            plan_meta_bytes(&plan.task_names, &plan.tensors),
            "plan body size must be computable without encoding"
        );
        let back = PackPlan::decode(&wire).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn group_payload_bytes_matches_real_encoding() {
        let mut rng = Rng::new(41);
        for (len, bits, group) in [(512usize, 3u8, 128usize), (1024, 2, 256), (96, 7, 32)] {
            let mut v = vec![0.0f32; len];
            rng.fill_normal(&mut v, 0.05);
            let g = GroupQuantized::quantize(&v, bits, group).unwrap();
            assert_eq!(
                encode_group_payload(&g).len() as u64,
                group_payload_bytes(len, bits, group),
                "len={len} bits={bits} group={group}"
            );
        }
    }

    #[test]
    fn padded_and_groups() {
        let t = PlanTensor { name: "x".into(), shape: vec![100], group: 64 };
        assert_eq!(t.numel(), 100);
        assert_eq!(t.padded(), 128);
        assert_eq!(t.n_groups(), 2);
        let exact = PlanTensor { name: "y".into(), shape: vec![64, 2], group: 64 };
        assert_eq!(exact.padded(), 128);
    }

    #[test]
    fn expected_sections_cover_every_slot() {
        let plan = sample_plan();
        let sections = plan.expected_sections();
        // One base (the rtvq-arm tensor) + 2 tasks x 2 tensors.
        assert_eq!(sections.len(), 1 + 4);
        assert!(sections
            .iter()
            .any(|(n, r)| n == "__base__/head/b" && *r == SectionRole::Base { tensor: 1 }));
        assert!(sections
            .iter()
            .any(|(n, r)| n == "task01/blk00/w"
                && *r == SectionRole::Task { task: 1, tensor: 0 }));
        // Spec lookups agree with the arms.
        assert_eq!(
            plan.section_spec(SectionRole::Base { tensor: 1 }),
            SectionSpec::Dense { bits: 3, group: 33, len: 33 }
        );
        assert_eq!(
            plan.section_spec(SectionRole::Task { task: 0, tensor: 0 }),
            SectionSpec::Dense { bits: 4, group: 128, len: 512 }
        );
        assert_eq!(
            plan.section_spec(SectionRole::Task { task: 0, tensor: 1 }),
            SectionSpec::Dense { bits: 2, group: 33, len: 33 }
        );
        assert_eq!(
            plan.expected_section_kind(SectionRole::Task { task: 0, tensor: 0 }),
            PayloadKind::Group
        );
    }

    #[test]
    fn planned_file_bytes_is_fixed_plus_arms() {
        let plan = sample_plan();
        let arms: u64 = plan.assignments.iter().map(|a| a.cost_bytes).sum();
        assert_eq!(
            plan.planned_file_bytes(),
            fixed_file_bytes(&plan.task_names, &plan.tensors) + arms
        );
        assert!(plan.ideal_code_bytes() < plan.planned_file_bytes());
        assert_eq!(plan.params_per_task(), 32 * 16 + 33);
    }

    #[test]
    fn validate_rejects_malformed_plans() {
        let good = sample_plan();

        let mut bad = good.clone();
        bad.task_names[1] = "task00".into();
        assert!(bad.validate().is_err(), "duplicate task name");

        let mut bad = good.clone();
        bad.task_names[0] = "a/b".into();
        assert!(bad.validate().is_err(), "slash in task name");

        let mut bad = good.clone();
        bad.assignments[0].cost_bytes += 1;
        assert!(bad.validate().is_err(), "cost mismatch");

        let mut bad = good.clone();
        bad.tensors[0].group = 0;
        assert!(bad.validate().is_err(), "zero group");

        let mut bad = good.clone();
        bad.assignments[0].error = f64::NAN;
        assert!(bad.validate().is_err(), "NaN error");

        let mut bad = good.clone();
        bad.assignments.pop();
        assert!(bad.validate().is_err(), "assignment count");
    }

    fn sparse_plan() -> PackPlan {
        let task_names = vec!["task00".to_string(), "task01".to_string()];
        let tensors = vec![
            PlanTensor { name: "blk00/w".into(), shape: vec![32, 16], group: 128 },
            PlanTensor { name: "loc00/w".into(), shape: vec![30, 10], group: 100 },
        ];
        let arms = [Arm::Dare { drop_pct: 90, bits: 4 }, Arm::Tall { keep_pct: 25, bits: 3 }];
        let assignments = tensors
            .iter()
            .zip(arms)
            .map(|(t, arm)| Assignment {
                arm,
                cost_bytes: arm_cost_bytes(&task_names, t, arm),
                error: 1.5,
            })
            .collect();
        PackPlan { budget_bytes: 1 << 19, task_names, tensors, assignments }
    }

    #[test]
    fn sparse_arm_survivor_arithmetic_is_exact() {
        let dare = Arm::Dare { drop_pct: 90, bits: 4 };
        assert_eq!(dare.survivors(512), Some(512 - 512 * 90 / 100));
        assert_eq!(dare.survivors(1), Some(1), "tiny tensors keep >= 1 survivor");
        let tall = Arm::Tall { keep_pct: 25, bits: 3 };
        assert_eq!(tall.survivors(1000), Some(250));
        assert_eq!(tall.survivors(3), Some(1));
        assert!((dare.rescale(512, 52) - 512.0 / 52.0).abs() < 1e-6);
        assert_eq!(tall.rescale(1000, 250), 1.0);
        assert_eq!(Arm::Tvq { bits: 4 }.survivors(512), None);
    }

    #[test]
    fn sparse_plan_roundtrips_at_wire_v2() {
        let plan = sparse_plan();
        plan.validate().unwrap();
        assert!(plan.has_sparse_arms());
        let wire = plan.encode();
        assert_eq!(wire[0], PLAN_WIRE_VERSION_SPARSE);
        assert_eq!(
            wire.len() as u64,
            plan_meta_bytes(&plan.task_names, &plan.tensors),
            "plan body size must stay arm-independent"
        );
        let back = PackPlan::decode(&wire).unwrap();
        assert_eq!(back, plan);
        // Dense plans still serialize at v1 (byte-compatible with PR 2).
        assert_eq!(sample_plan().encode()[0], PLAN_WIRE_VERSION);
        // Spec lookups carry the survivor geometry.
        assert_eq!(
            plan.section_spec(SectionRole::Task { task: 1, tensor: 0 }),
            SectionSpec::Sparse { bits: 4, group: 128, dense_len: 512, survivors: 52 }
        );
        assert_eq!(
            plan.expected_section_kind(SectionRole::Task { task: 1, tensor: 0 }),
            PayloadKind::SparseGroup
        );
    }

    #[test]
    fn sparse_arm_kind_rejected_in_v1_body() {
        let mut wire = sparse_plan().encode();
        assert_eq!(wire[0], PLAN_WIRE_VERSION_SPARSE);
        wire[0] = PLAN_WIRE_VERSION;
        let err = PackPlan::decode(&wire).unwrap_err().to_string();
        assert!(err.contains("wire v2"), "got: {err}");
    }

    #[test]
    fn sparse_payload_bytes_matches_real_encoding() {
        use crate::quant::SparseGroupQuantized;
        use crate::registry::container::encode_sparse_payload;
        let mut rng = Rng::new(43);
        for (padded, arm) in [
            (512usize, Arm::Dare { drop_pct: 90, bits: 4 }),
            (512, Arm::Tall { keep_pct: 25, bits: 3 }),
            (100, Arm::Tall { keep_pct: 12, bits: 2 }),
        ] {
            let group = 64usize;
            let mut v = vec![0.0f32; padded];
            rng.fill_normal(&mut v, 0.05);
            let k = arm.survivors(padded).unwrap();
            let keep: Vec<usize> = (0..k).collect();
            let (bits, pct) = match arm {
                Arm::Dare { drop_pct, bits } => (bits, drop_pct),
                Arm::Tall { keep_pct, bits } => (bits, keep_pct),
                _ => unreachable!(),
            };
            let s = SparseGroupQuantized::quantize_indices(
                &v,
                &keep,
                arm.rescale(padded, k),
                bits,
                group,
            )
            .unwrap();
            assert_eq!(
                encode_sparse_payload(&s).len() as u64,
                sparse_payload_bytes(padded, k, bits, group),
                "padded={padded} pct={pct} bits={bits}"
            );
        }
    }

    fn onebit_plan() -> PackPlan {
        let task_names = vec!["task00".to_string(), "task01".to_string()];
        let tensors = vec![
            PlanTensor { name: "blk00/w".into(), shape: vec![32, 16], group: 128 },
            PlanTensor { name: "head/b".into(), shape: vec![33], group: 33 },
        ];
        let arms = [
            Arm::OneBit { per_tensor_scale: false },
            Arm::OneBit { per_tensor_scale: true },
        ];
        let assignments = tensors
            .iter()
            .zip(arms)
            .map(|(t, arm)| Assignment {
                arm,
                cost_bytes: arm_cost_bytes(&task_names, t, arm),
                error: 2.0,
            })
            .collect();
        PackPlan { budget_bytes: 1 << 18, task_names, tensors, assignments }
    }

    #[test]
    fn onebit_plan_roundtrips_at_wire_v3() {
        let plan = onebit_plan();
        plan.validate().unwrap();
        assert!(plan.has_onebit_arms());
        assert!(!plan.has_sparse_arms());
        let wire = plan.encode();
        assert_eq!(wire[0], PLAN_WIRE_VERSION_BINARY);
        assert_eq!(
            wire.len() as u64,
            plan_meta_bytes(&plan.task_names, &plan.tensors),
            "plan body size must stay arm-independent"
        );
        let back = PackPlan::decode(&wire).unwrap();
        assert_eq!(back, plan);
        // Per-group vs per-tensor scale geometry in the spec lookups.
        assert_eq!(
            plan.section_spec(SectionRole::Task { task: 0, tensor: 0 }),
            SectionSpec::Binary { group: 128, len: 512 }
        );
        assert_eq!(
            plan.section_spec(SectionRole::Task { task: 1, tensor: 1 }),
            SectionSpec::Binary { group: 33, len: 33 }
        );
        assert_eq!(
            plan.expected_section_kind(SectionRole::Task { task: 0, tensor: 0 }),
            PayloadKind::BinarySwitch
        );
        assert_eq!(Arm::OneBit { per_tensor_scale: false }.label(), "1BIT-G");
        assert_eq!(Arm::OneBit { per_tensor_scale: true }.label(), "1BIT-T");
        // The ideal-code accounting counts exactly the sign bitmaps.
        assert_eq!(plan.ideal_code_bytes(), 2 * (512u64.div_ceil(8) + 33u64.div_ceil(8)));
    }

    #[test]
    fn binary_arm_kind_rejected_below_wire_v3() {
        let mut wire = onebit_plan().encode();
        assert_eq!(wire[0], PLAN_WIRE_VERSION_BINARY);
        for ver in [PLAN_WIRE_VERSION, PLAN_WIRE_VERSION_SPARSE] {
            wire[0] = ver;
            let err = PackPlan::decode(&wire).unwrap_err().to_string();
            assert!(err.contains("wire v3"), "ver={ver}: got {err}");
        }
    }

    #[test]
    fn binary_payload_bytes_matches_real_encoding() {
        use crate::quant::BinarySwitch;
        use crate::registry::container::encode_binary_payload;
        let mut rng = Rng::new(47);
        for (padded, group) in [(512usize, 128usize), (512, 512), (96, 32), (33, 33)] {
            let mut v = vec![0.0f32; padded];
            rng.fill_normal(&mut v, 0.05);
            let b = BinarySwitch::quantize(&v, group).unwrap();
            assert_eq!(
                encode_binary_payload(&b).len() as u64,
                binary_payload_bytes(padded, group),
                "padded={padded} group={group}"
            );
        }
    }

    #[test]
    fn validate_rejects_bad_sparse_percentages() {
        let mut bad = sparse_plan();
        bad.assignments[0].arm = Arm::Dare { drop_pct: 0, bits: 4 };
        bad.assignments[0].cost_bytes =
            arm_cost_bytes(&bad.task_names, &bad.tensors[0], bad.assignments[0].arm);
        assert!(bad.validate().is_err(), "drop_pct 0");
        let mut bad = sparse_plan();
        bad.assignments[1].arm = Arm::Tall { keep_pct: 100, bits: 3 };
        bad.assignments[1].cost_bytes =
            arm_cost_bytes(&bad.task_names, &bad.tensors[1], bad.assignments[1].arm);
        assert!(bad.validate().is_err(), "keep_pct 100");
    }

    #[test]
    fn decode_rejects_corruption() {
        let wire = sample_plan().encode();
        // Truncation at every prefix must fail cleanly, never panic.
        for cut in [0usize, 1, 8, 12, 20, wire.len() - 1] {
            assert!(PackPlan::decode(&wire[..cut]).is_err(), "cut at {cut}");
        }
        // Trailing garbage.
        let mut padded = wire.clone();
        padded.push(0);
        assert!(PackPlan::decode(&padded).is_err());
        // Wrong wire version.
        let mut bad = wire.clone();
        bad[0] = 9;
        assert!(PackPlan::decode(&bad).is_err());
        // Absurd task count must bail before allocating.
        let mut bad = wire;
        bad[9..13].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = PackPlan::decode(&bad).unwrap_err().to_string();
        assert!(err.contains("claims"), "got: {err}");
    }
}
