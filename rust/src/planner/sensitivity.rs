//! Quantization-sensitivity probing: measure, for every tensor (layer)
//! of the task suite and every candidate [`Arm`], the exact byte cost and
//! the reconstruction error the arm would incur.
//!
//! This is the paper's Section 4.4 observation made operational: layers
//! differ by orders of magnitude in how much error a given bit width
//! induces (the task-vector range varies per layer), so a fixed byte
//! budget is better spent unevenly.  The probe quantizes each layer's
//! flat per-task slices under each candidate arm — per-task group
//! quantization ([`Arm::Tvq`]), shared-base/residual splits
//! ([`Arm::Rtvq`], error-corrected exactly like
//! [`Rtvq::quantize`](crate::quant::Rtvq::quantize)), and the sparse
//! families ([`Arm::Dare`] drop-and-rescale, [`Arm::Tall`] task
//! localization against the multi-task vector), and the 1-bit binary
//! switch ([`Arm::OneBit`], measured on its served ±scale reconstruction)
//! — and records the sum-of-squares reconstruction error next to the
//! arm's exact file-byte cost from [`arm_cost_bytes`].  The solver
//! ([`super::solve`]) then trades these off greedily.
//!
//! Sparse arms are measured on exactly what would be served: survivors
//! rescaled (DARE) or kept as-is (TALL), masked-out weights at 0 — so a
//! DARE arm's SSE includes its rescale distortion, which is why the
//! frontier only picks it where dropping genuinely beats low-bit codes.

use anyhow::{bail, Result};

use std::collections::HashMap;

use super::plan::{arm_cost_bytes, Arm, PlanTensor};
use super::{
    binary_section, mean_flat, padded_flat, quantize_offset, sparse_section, PlannerConfig,
};
use crate::checkpoint::Checkpoint;
use crate::quant::GroupQuantized;
use crate::tensor::Tensor;
use crate::util::pool::Pool;
use crate::util::stats::sse;

/// One probed candidate for one tensor.
#[derive(Clone, Copy, Debug)]
pub struct ArmStat {
    pub arm: Arm,
    /// Exact bytes the arm adds to the registry file.
    pub cost_bytes: u64,
    /// Sum over tasks of squared L2 reconstruction error.
    pub error: f64,
}

/// All probed candidates for one tensor.
#[derive(Clone, Debug)]
pub struct TensorProfile {
    pub tensor: PlanTensor,
    /// One entry per candidate arm, in probe order.
    pub arms: Vec<ArmStat>,
}

/// The full probe result the solver consumes.
#[derive(Clone, Debug)]
pub struct SensitivityProfile {
    pub task_names: Vec<String>,
    pub profiles: Vec<TensorProfile>,
}

/// Probe every tensor of the suite under every candidate arm of `cfg`.
///
/// `fts` are fine-tuned checkpoints; task vectors tau_t = ft_t - pre are
/// formed internally.  Task names follow the registry convention
/// (`task00`, `task01`, ...).
///
/// Tensors are probed independently and fanned out across the shared
/// [`Pool`]; results return in tensor order and each tensor's arithmetic
/// is self-contained, so the profile — and therefore every plan solved
/// from it — is identical at every thread count.
pub fn probe(
    pre: &Checkpoint,
    fts: &[Checkpoint],
    cfg: &PlannerConfig,
) -> Result<SensitivityProfile> {
    probe_with_pool(pre, fts, cfg, Pool::global())
}

/// [`probe`] on an explicit pool.
pub fn probe_with_pool(
    pre: &Checkpoint,
    fts: &[Checkpoint],
    cfg: &PlannerConfig,
    pool: &Pool,
) -> Result<SensitivityProfile> {
    if fts.is_empty() {
        bail!("sensitivity probe needs at least one fine-tuned checkpoint");
    }
    cfg.check()?;
    let task_names: Vec<String> = (0..fts.len()).map(|t| format!("task{t:02}")).collect();
    let taus: Vec<Checkpoint> = fts.iter().map(|ft| ft.sub(pre)).collect::<Result<_>>()?;

    let tensors: Vec<(&str, &Tensor)> = pre.iter().collect();
    let profiles = pool.try_map(tensors, |_, (name, t)| {
        probe_tensor(name, t, &taus, &task_names, cfg)
    })?;
    Ok(SensitivityProfile { task_names, profiles })
}

/// Probe one tensor under every candidate arm — the unit of work the
/// pool fans out.
fn probe_tensor(
    name: &str,
    t: &Tensor,
    taus: &[Checkpoint],
    task_names: &[String],
    cfg: &PlannerConfig,
) -> Result<TensorProfile> {
    let numel = t.numel();
    if numel == 0 {
        bail!("tensor {name:?} has zero elements; cannot plan it");
    }
    let tensor = PlanTensor {
        name: name.to_string(),
        shape: t.shape().to_vec(),
        group: cfg.group.min(numel),
    };
    let padded = tensor.padded();
    let group = tensor.group;

    // Per-task padded flats and their task mean (the shared base the
    // RTVQ arms decompose against) — via the same helpers the writer
    // compiles with, so probed errors match packed payloads exactly.
    let flats: Vec<Vec<f32>> = taus
        .iter()
        .map(|tau| padded_flat(tau, name, padded))
        .collect::<Result<_>>()?;
    let base = mean_flat(taus, &tensor)?;

    let mut arms = Vec::new();
    for &bits in &cfg.tvq_bits {
        let mut error = 0.0;
        for flat in &flats {
            // Shared helper (quant::group) — the same pad+quantize+SSE
            // path the granularity ablation measures with.
            error += GroupQuantized::quantize(flat, bits, group)?.sse_against(flat);
        }
        let arm = Arm::Tvq { bits };
        arms.push(ArmStat {
            arm,
            cost_bytes: arm_cost_bytes(task_names, &tensor, arm),
            error,
        });
    }
    // Dequantized bases are shared across arms with the same
    // base_bits (the default config repeats each width), so each
    // distinct width quantizes the base exactly once per tensor.
    let mut hat_cache: HashMap<u8, Vec<f32>> = HashMap::new();
    for &(base_bits, offset_bits) in &cfg.rtvq_arms {
        if !hat_cache.contains_key(&base_bits) {
            let qbase = GroupQuantized::quantize(&base, base_bits, group)?;
            hat_cache.insert(base_bits, qbase.dequantize());
        }
        let base_hat = &hat_cache[&base_bits];
        let mut error = 0.0;
        for flat in &flats {
            let qoff = quantize_offset(flat, base_hat, offset_bits, group)?;
            let off_hat = qoff.dequantize();
            let rec: Vec<f32> =
                off_hat.iter().zip(base_hat).map(|(&o, &b)| o + b).collect();
            error += sse(flat, &rec);
        }
        let arm = Arm::Rtvq { base_bits, offset_bits };
        arms.push(ArmStat {
            arm,
            cost_bytes: arm_cost_bytes(task_names, &tensor, arm),
            error,
        });
    }
    // Sparse arms: quantize through the same sparse_section path the
    // writer packs, and measure the error of the *served* dense
    // reconstruction (zeros at masked-out weights).  The multi-task
    // vector is summed from the flats already in scope (same task
    // order and element order as the writer's sum_flat, so the masks
    // stay bit-identical).
    let mtl = if cfg.tall_arms.is_empty() {
        None
    } else {
        let mut acc = vec![0.0f32; padded];
        for flat in &flats {
            for (a, &x) in acc.iter_mut().zip(flat) {
                *a += x;
            }
        }
        Some(acc)
    };
    let sparse_candidates = cfg
        .dare_arms
        .iter()
        .map(|&(drop_pct, bits)| Arm::Dare { drop_pct, bits })
        .chain(
            cfg.tall_arms
                .iter()
                .map(|&(keep_pct, bits)| Arm::Tall { keep_pct, bits }),
        );
    for arm in sparse_candidates {
        let mut error = 0.0;
        for (t, flat) in flats.iter().enumerate() {
            let s = sparse_section(arm, &tensor, t, flat, mtl.as_deref())?;
            error += sse(flat, &s.dequantize());
        }
        arms.push(ArmStat {
            arm,
            cost_bytes: arm_cost_bytes(task_names, &tensor, arm),
            error,
        });
    }
    // Binary arms: quantize through the same binary_section path the
    // writer packs, and measure the served ±scale reconstruction.
    for &per_tensor_scale in &cfg.onebit_arms {
        let arm = Arm::OneBit { per_tensor_scale };
        let mut error = 0.0;
        for flat in &flats {
            let b = binary_section(arm, &tensor, flat)?;
            error += sse(flat, &b.dequantize());
        }
        arms.push(ArmStat {
            arm,
            cost_bytes: arm_cost_bytes(task_names, &tensor, arm),
            error,
        });
    }
    // Fail closed on non-finite weights (diverged checkpoints): a
    // NaN error must become a pointed Err here, not a solver panic.
    for a in &arms {
        if !a.error.is_finite() {
            bail!(
                "tensor {name:?}: arm {} probed non-finite error {} \
                 (non-finite weights in the task suite?)",
                a.arm.label(),
                a.error
            );
        }
    }
    Ok(TensorProfile { tensor, arms })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    /// Common-drift suite: the regime where RTVQ arms shine.
    fn suite(n_tasks: usize, seed: u64) -> (Checkpoint, Vec<Checkpoint>) {
        let mut rng = Rng::new(seed);
        let mut pre = Checkpoint::new();
        pre.insert("blk00/w", Tensor::randn(&[48, 32], 0.3, &mut rng));
        pre.insert("head/w", Tensor::randn(&[40, 10], 0.3, &mut rng));
        let mut drift = Checkpoint::new();
        for (name, t) in pre.iter() {
            drift.insert(name, Tensor::randn(t.shape(), 0.02, &mut rng));
        }
        let fts = (0..n_tasks)
            .map(|_| {
                let mut off = Checkpoint::new();
                for (name, t) in pre.iter() {
                    off.insert(name, Tensor::randn(t.shape(), 0.004, &mut rng));
                }
                pre.add(&drift).unwrap().add(&off).unwrap()
            })
            .collect();
        (pre, fts)
    }

    #[test]
    fn error_decreases_with_bits() {
        let (pre, fts) = suite(4, 1);
        let cfg = PlannerConfig {
            group: 128,
            tvq_bits: vec![2, 4, 8],
            rtvq_arms: vec![],
            dare_arms: vec![],
            tall_arms: vec![],
            onebit_arms: vec![],
        };
        let prof = probe(&pre, &fts, &cfg).unwrap();
        for p in &prof.profiles {
            assert!(
                p.arms[0].error > p.arms[1].error && p.arms[1].error > p.arms[2].error,
                "{:?}: {:?}",
                p.tensor.name,
                p.arms.iter().map(|a| a.error).collect::<Vec<_>>()
            );
            assert!(
                p.arms[0].cost_bytes < p.arms[1].cost_bytes
                    && p.arms[1].cost_bytes < p.arms[2].cost_bytes
            );
        }
    }

    #[test]
    fn rtvq_arm_beats_matching_tvq_under_common_drift() {
        // With a strong shared drift, a B3O2 arm should beat plain 2-bit
        // TVQ on error while costing barely more (the base amortizes).
        let (pre, fts) = suite(8, 2);
        let cfg = PlannerConfig {
            group: 128,
            tvq_bits: vec![2],
            rtvq_arms: vec![(3, 2)],
            dare_arms: vec![],
            tall_arms: vec![],
            onebit_arms: vec![],
        };
        let prof = probe(&pre, &fts, &cfg).unwrap();
        for p in &prof.profiles {
            let tvq2 = &p.arms[0];
            let rtvq = &p.arms[1];
            assert!(
                rtvq.error < tvq2.error,
                "{}: rtvq {} vs tvq2 {}",
                p.tensor.name,
                rtvq.error,
                tvq2.error
            );
        }
    }

    #[test]
    fn tall_arm_beats_dense_low_bits_on_localized_deltas() {
        // Each task perturbs its own small subset of weights; TALL's
        // localization mask keeps exactly those entries, so at a byte
        // cost comparable to dense 2-bit codes it should reconstruct far
        // better (the regime arXiv 2405.07813 exploits).
        let mut rng = Rng::new(9);
        let mut pre = Checkpoint::new();
        pre.insert("loc/w", Tensor::randn(&[64, 32], 0.3, &mut rng));
        let n = 64 * 32;
        let fts: Vec<Checkpoint> = (0..4)
            .map(|_| {
                let mut ft = pre.clone();
                for (_, t) in ft.iter_mut() {
                    for v in t.data_mut().iter_mut().take(n) {
                        if rng.f32() < 0.08 {
                            *v += rng.normal_f32(0.1);
                        }
                    }
                }
                ft
            })
            .collect();
        let cfg = PlannerConfig {
            group: 256,
            tvq_bits: vec![2],
            rtvq_arms: vec![],
            dare_arms: vec![],
            tall_arms: vec![(25, 4)],
            onebit_arms: vec![],
        };
        let prof = probe(&pre, &fts, &cfg).unwrap();
        let p = &prof.profiles[0];
        let tvq2 = &p.arms[0];
        let tall = &p.arms[1];
        assert!(matches!(tall.arm, Arm::Tall { .. }));
        assert!(
            tall.error < tvq2.error,
            "tall {} should beat dense 2-bit {} on localized deltas",
            tall.error,
            tvq2.error
        );
        assert!(
            tall.cost_bytes < tvq2.cost_bytes,
            "tall mask+25%x4b ({} B) should undercut dense 2-bit ({} B)",
            tall.cost_bytes,
            tvq2.cost_bytes
        );
    }

    #[test]
    fn dare_arm_is_probed_with_rescale_distortion() {
        let (pre, fts) = suite(3, 7);
        let cfg = PlannerConfig {
            group: 128,
            tvq_bits: vec![4],
            rtvq_arms: vec![],
            dare_arms: vec![(50, 4)],
            tall_arms: vec![],
            onebit_arms: vec![],
        };
        let prof = probe(&pre, &fts, &cfg).unwrap();
        for p in &prof.profiles {
            let dare = &p.arms[1];
            assert!(matches!(dare.arm, Arm::Dare { .. }));
            // Dropping half of a dense Gaussian tau and rescaling x2 must
            // cost real error — the probe measures the served vector, not
            // the merge expectation.
            assert!(dare.error > p.arms[0].error);
            assert!(dare.cost_bytes < p.arms[0].cost_bytes);
            assert!(dare.error.is_finite());
        }
    }

    #[test]
    fn onebit_arm_is_probed_as_the_cheapest_candidate() {
        let (pre, fts) = suite(3, 8);
        let cfg = PlannerConfig {
            group: 128,
            tvq_bits: vec![1, 4],
            rtvq_arms: vec![],
            dare_arms: vec![],
            tall_arms: vec![],
            onebit_arms: vec![false, true],
        };
        let prof = probe(&pre, &fts, &cfg).unwrap();
        for p in &prof.profiles {
            let tvq1 = &p.arms[0];
            let tvq4 = &p.arms[1];
            let per_group = &p.arms[2];
            let per_tensor = &p.arms[3];
            assert_eq!(per_group.arm, Arm::OneBit { per_tensor_scale: false });
            assert_eq!(per_tensor.arm, Arm::OneBit { per_tensor_scale: true });
            // 1-bit codes with no zero points undercut even 1-bit affine
            // TVQ (which carries scale+zp pairs), and the per-tensor
            // scale undercuts per-group.
            assert!(per_group.cost_bytes < tvq1.cost_bytes);
            assert!(per_tensor.cost_bytes < per_group.cost_bytes);
            assert!(per_tensor.cost_bytes < tvq4.cost_bytes);
            // More scales can't hurt reconstruction.
            assert!(per_group.error <= per_tensor.error);
            assert!(per_group.error.is_finite() && per_tensor.error.is_finite());
            // Cost bookkeeping is the shared byte-exact arithmetic.
            assert_eq!(
                per_group.cost_bytes,
                arm_cost_bytes(&prof.task_names, &p.tensor, per_group.arm)
            );
        }
    }

    #[test]
    fn tiny_tensor_group_clamps_to_numel() {
        let mut rng = Rng::new(3);
        let mut pre = Checkpoint::new();
        pre.insert("b", Tensor::randn(&[7], 0.1, &mut rng));
        let mut ft = pre.clone();
        for (_, t) in ft.iter_mut() {
            for v in t.data_mut() {
                *v += 0.01;
            }
        }
        let cfg = PlannerConfig::default();
        let prof = probe(&pre, &[ft], &cfg).unwrap();
        assert_eq!(prof.profiles[0].tensor.group, 7);
        assert_eq!(prof.profiles[0].tensor.padded(), 7);
    }

    #[test]
    fn empty_suite_rejected() {
        let (pre, _) = suite(1, 4);
        assert!(probe(&pre, &[], &PlannerConfig::default()).is_err());
    }
}
