//! The bit-allocation solver: greedy marginal-error-per-byte under an
//! exact byte budget.
//!
//! Each tensor's probed arms ([`SensitivityProfile`]) are first reduced
//! to their Pareto frontier (strictly less error for strictly more
//! bytes), then to the frontier's lower convex hull so that successive
//! upgrades have strictly decreasing error-reduction-per-byte.  The
//! solver starts every tensor at its cheapest arm and walks a single
//! globally-sorted sequence of upgrade moves (best gain first), stopping
//! at the first move the budget cannot absorb.
//!
//! Because the move sequence is computed from the profile alone — the
//! budget only decides how long a *prefix* of it is applied — the solver
//! degrades **monotonically by construction**: for budgets `B1 >= B2`,
//! `solve(B1)` applies a superset of `solve(B2)`'s moves, so its total
//! error is never larger.  The planner's property tests pin exactly this.

use anyhow::{bail, Result};

use super::plan::{fixed_file_bytes, Assignment, PackPlan};
use super::sensitivity::{ArmStat, SensitivityProfile};

/// One upgrade step on a tensor's convex frontier.
struct Move {
    tensor: usize,
    /// Index into the tensor's hull this move upgrades *to*.
    step: usize,
    dcost: u64,
    derr: f64,
    /// Error reduction per byte — the greedy key.
    gain: f64,
}

/// Pareto frontier: sort by cost, keep arms that strictly improve error.
fn pareto(arms: &[ArmStat]) -> Vec<ArmStat> {
    let mut sorted: Vec<ArmStat> = arms.to_vec();
    // total_cmp keeps the comparator total even on hand-built profiles
    // with non-finite errors (probe() rejects those at the source).
    sorted.sort_by(|a, b| {
        a.cost_bytes.cmp(&b.cost_bytes).then(a.error.total_cmp(&b.error))
    });
    let mut front: Vec<ArmStat> = Vec::new();
    for arm in sorted {
        match front.last() {
            Some(last) if arm.error >= last.error => {} // dominated
            _ => front.push(arm),
        }
    }
    front
}

/// Lower convex hull of a Pareto frontier (cost ascending, error strictly
/// descending): drop points whose step gain is not strictly below the
/// previous step's, so the greedy merge of per-tensor steps is globally
/// optimal for the fractional relaxation.
fn convex_hull(front: Vec<ArmStat>) -> Vec<ArmStat> {
    let mut hull: Vec<ArmStat> = Vec::new();
    for arm in front {
        while hull.len() >= 2 {
            let a = &hull[hull.len() - 2];
            let b = &hull[hull.len() - 1];
            let gain_ab = (a.error - b.error) / (b.cost_bytes - a.cost_bytes) as f64;
            let gain_bc = (b.error - arm.error) / (arm.cost_bytes - b.cost_bytes) as f64;
            if gain_bc >= gain_ab {
                hull.pop();
            } else {
                break;
            }
        }
        hull.push(arm);
    }
    hull
}

/// Solve the allocation for `budget_bytes` (total registry **file**
/// bytes, index included).  Errors if even the cheapest feasible plan
/// exceeds the budget, naming the minimum.
pub fn solve(profile: &SensitivityProfile, budget_bytes: u64) -> Result<PackPlan> {
    if profile.profiles.is_empty() {
        bail!("cannot solve an empty sensitivity profile");
    }
    let hulls: Vec<Vec<ArmStat>> = profile
        .profiles
        .iter()
        .map(|p| {
            let hull = convex_hull(pareto(&p.arms));
            if hull.is_empty() {
                bail!("tensor {:?} probed zero candidate arms", p.tensor.name);
            }
            Ok(hull)
        })
        .collect::<Result<_>>()?;

    let tensors: Vec<_> = profile.profiles.iter().map(|p| p.tensor.clone()).collect();
    let fixed = fixed_file_bytes(&profile.task_names, &tensors);
    let mut chosen: Vec<usize> = vec![0; hulls.len()];
    let mut total: u64 = fixed + hulls.iter().map(|h| h[0].cost_bytes).sum::<u64>();
    if total > budget_bytes {
        bail!(
            "budget {budget_bytes} B is below the minimum feasible plan \
             ({total} B at the cheapest arms)"
        );
    }

    // The budget-independent move sequence: every hull step of every
    // tensor, best gain first.  Per-tensor hull gains strictly decrease,
    // so the global sort preserves per-tensor step order; ties break
    // deterministically by (tensor, step).
    let mut moves: Vec<Move> = Vec::new();
    for (l, hull) in hulls.iter().enumerate() {
        for step in 1..hull.len() {
            let dcost = hull[step].cost_bytes - hull[step - 1].cost_bytes;
            let derr = hull[step - 1].error - hull[step].error;
            moves.push(Move { tensor: l, step, dcost, derr, gain: derr / dcost as f64 });
        }
    }
    moves.sort_by(|a, b| {
        b.gain
            .total_cmp(&a.gain)
            .then(a.tensor.cmp(&b.tensor))
            .then(a.step.cmp(&b.step))
    });

    for m in &moves {
        if total + m.dcost > budget_bytes {
            // Stop, don't skip: acceptance must depend only on the
            // sequence prefix for the monotone-degradation guarantee.
            break;
        }
        debug_assert_eq!(chosen[m.tensor], m.step - 1, "hull steps apply in order");
        debug_assert!(m.derr >= 0.0);
        chosen[m.tensor] = m.step;
        total += m.dcost;
    }

    let assignments: Vec<Assignment> = hulls
        .iter()
        .zip(&chosen)
        .map(|(hull, &i)| Assignment {
            arm: hull[i].arm,
            cost_bytes: hull[i].cost_bytes,
            error: hull[i].error,
        })
        .collect();
    let plan = PackPlan {
        budget_bytes,
        task_names: profile.task_names.clone(),
        tensors,
        assignments,
    };
    plan.validate()?;
    debug_assert_eq!(plan.planned_file_bytes(), total);
    if plan.planned_file_bytes() > budget_bytes {
        bail!(
            "solver bug: planned {} B exceeds budget {budget_bytes} B",
            plan.planned_file_bytes()
        );
    }
    Ok(plan)
}

/// The minimum budget any plan for `profile` can satisfy (cheapest arm
/// everywhere) — useful for sizing sweeps and error messages.
pub fn min_feasible_bytes(profile: &SensitivityProfile) -> u64 {
    let tensors: Vec<_> = profile.profiles.iter().map(|p| p.tensor.clone()).collect();
    fixed_file_bytes(&profile.task_names, &tensors)
        + profile
            .profiles
            .iter()
            .map(|p| p.arms.iter().map(|a| a.cost_bytes).min().unwrap_or(0))
            .sum::<u64>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::plan::{arm_cost_bytes, Arm, PlanTensor};
    use crate::planner::sensitivity::TensorProfile;

    /// Hand-built profile: two tensors with different sensitivity so the
    /// solver must allocate unevenly.
    fn profile() -> SensitivityProfile {
        let task_names = vec!["task00".to_string(), "task01".to_string()];
        let mk = |name: &str, numel: usize, errs: &[(u8, f64)]| {
            let tensor =
                PlanTensor { name: name.into(), shape: vec![numel], group: numel.min(64) };
            let arms = errs
                .iter()
                .map(|&(bits, error)| {
                    let arm = Arm::Tvq { bits };
                    ArmStat {
                        arm,
                        cost_bytes: arm_cost_bytes(&task_names, &tensor, arm),
                        error,
                    }
                })
                .collect();
            TensorProfile { tensor, arms }
        };
        SensitivityProfile {
            task_names: task_names.clone(),
            profiles: vec![
                // "loud" tensor: error falls steeply with bits.
                mk("loud", 1024, &[(1, 400.0), (2, 100.0), (4, 6.0), (8, 0.1)]),
                // "quiet" tensor: nearly flat — extra bits are wasted.
                mk("quiet", 1024, &[(1, 2.0), (2, 1.5), (4, 1.2), (8, 1.1)]),
            ],
        }
    }

    #[test]
    fn pareto_drops_dominated_arms() {
        let t = PlanTensor { name: "x".into(), shape: vec![64], group: 64 };
        let names = vec!["task00".to_string()];
        let mk = |bits: u8, error: f64| {
            let arm = Arm::Tvq { bits };
            ArmStat { arm, cost_bytes: arm_cost_bytes(&names, &t, arm), error }
        };
        // 3-bit with *worse* error than 2-bit is dominated.
        let front = pareto(&[mk(2, 1.0), mk(3, 1.5), mk(4, 0.5)]);
        assert_eq!(front.len(), 2);
        assert_eq!(front[0].arm, Arm::Tvq { bits: 2 });
        assert_eq!(front[1].arm, Arm::Tvq { bits: 4 });
    }

    #[test]
    fn budget_is_respected_and_spent_on_the_loud_tensor() {
        let prof = profile();
        let min = min_feasible_bytes(&prof);
        // Enough budget for one tensor to go high-bit, not both.
        let extra = {
            let t = &prof.profiles[0].tensor;
            arm_cost_bytes(&prof.task_names, t, Arm::Tvq { bits: 8 })
                - arm_cost_bytes(&prof.task_names, t, Arm::Tvq { bits: 1 })
        };
        let plan = solve(&prof, min + extra).unwrap();
        assert!(plan.planned_file_bytes() <= min + extra);
        // The loud tensor gets the bits; the quiet one stays cheap.
        let loud_bits = match plan.assignments[0].arm {
            Arm::Tvq { bits } => bits,
            _ => unreachable!(),
        };
        let quiet_bits = match plan.assignments[1].arm {
            Arm::Tvq { bits } => bits,
            _ => unreachable!(),
        };
        assert!(
            loud_bits > quiet_bits,
            "loud={loud_bits} quiet={quiet_bits} (allocation must be uneven)"
        );
    }

    #[test]
    fn dominating_sparse_arm_enters_the_frontier() {
        // A TALL arm cheaper than dense INT1 with lower error strictly
        // dominates it: the solver's cheapest plan starts there, and a
        // generous budget still upgrades away to the high-bit dense arm.
        let task_names = vec!["task00".to_string()];
        let tensor = PlanTensor { name: "loc".into(), shape: vec![1024], group: 64 };
        let mk = |arm: Arm, error: f64| ArmStat {
            arm,
            cost_bytes: arm_cost_bytes(&task_names, &tensor, arm),
            error,
        };
        let tall = Arm::Tall { keep_pct: 25, bits: 2 };
        let arms = vec![
            mk(Arm::Tvq { bits: 1 }, 100.0),
            mk(tall, 20.0),
            mk(Arm::Tvq { bits: 4 }, 1.0),
        ];
        assert!(
            arms[1].cost_bytes < arms[0].cost_bytes,
            "mask + 25% x 2b must undercut dense 1-bit for this test"
        );
        let prof = SensitivityProfile {
            task_names,
            profiles: vec![TensorProfile { tensor, arms }],
        };
        let min = min_feasible_bytes(&prof);
        let at_min = solve(&prof, min).unwrap();
        assert_eq!(at_min.assignments[0].arm, tall);
        assert!(at_min.has_sparse_arms());
        let roomy = solve(&prof, min * 4).unwrap();
        assert_eq!(roomy.assignments[0].arm, Arm::Tvq { bits: 4 });
    }

    #[test]
    fn infeasible_budget_errors_with_minimum() {
        let prof = profile();
        let min = min_feasible_bytes(&prof);
        let err = solve(&prof, min - 1).unwrap_err().to_string();
        assert!(err.contains("minimum feasible"), "got: {err}");
        assert!(solve(&prof, min).is_ok(), "exactly the minimum must be feasible");
    }

    #[test]
    fn error_degrades_monotonically_as_budget_shrinks() {
        let prof = profile();
        let min = min_feasible_bytes(&prof);
        let max = {
            let worst: u64 = prof
                .profiles
                .iter()
                .map(|p| p.arms.iter().map(|a| a.cost_bytes).max().unwrap())
                .sum();
            min + worst
        };
        let mut last_err = f64::INFINITY;
        let mut last_bytes = 0u64;
        let steps = 12u64;
        for i in 0..=steps {
            let budget = min + (max - min) * i / steps;
            let plan = solve(&prof, budget).unwrap();
            assert!(plan.planned_file_bytes() <= budget, "budget {budget} violated");
            assert!(
                plan.total_error() <= last_err,
                "budget {budget}: error {} regressed above {last_err}",
                plan.total_error()
            );
            assert!(plan.planned_file_bytes() >= last_bytes);
            last_err = plan.total_error();
            last_bytes = plan.planned_file_bytes();
        }
    }
}
