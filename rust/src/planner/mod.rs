//! Budget-aware pack planner — sensitivity-driven mixed-precision
//! allocation compiled into group-quantized registry payloads.
//!
//! The paper's memory claim (Section 4.4) rests on spending bits where
//! quantization hurts most.  Uniform TVQ/RTVQ registries give every layer
//! of every task the same width; this subsystem instead
//!
//! 1. **probes** per-layer sensitivity ([`sensitivity`]): the exact byte
//!    cost and reconstruction error of every candidate arm — per-task
//!    group quantization at 1..=8 bits, shared-base/offset RTVQ splits,
//!    the sparse families (DARE drop-and-rescale, TALL-mask task
//!    localization — masked-out weights at 0 bits), and the 1-bit binary
//!    switch (sign bitmap + scales, after 1bit-Merging / Binary Task
//!    Switch) — against the f32 task vectors;
//! 2. **solves** the allocation ([`solve`]): greedy
//!    marginal-error-per-byte over each tensor's convex cost/error
//!    frontier, under a caller byte budget measured in real file bytes
//!    (codes + group params + bitmasks + offset-table rows + the plan
//!    section itself), degrading monotonically as the budget shrinks; and
//! 3. **compiles** the winning [`PackPlan`] ([`plan`]) into a `QTVC`
//!    v3/v4/v5 registry of kind-2 [`GroupQuantized`], kind-4
//!    [`SparseGroupQuantized`] and kind-5 [`BinarySwitch`] sections (byte
//!    layout: `docs/WIRE_FORMAT.md`), served straight through the fused
//!    dequant-merge path ([`fused_merge`]).
//!
//! # Quickstart: plan → pack → serve
//!
//! ```no_run
//! use tvq::planner::{build_planned_registry, fused_merge, PlannerConfig};
//! use tvq::registry::{PackedRegistrySource, Registry};
//! use tvq::util::exec::ExecCtx;
//!
//! # fn main() -> anyhow::Result<()> {
//! # let (pre, fts): (tvq::checkpoint::Checkpoint, Vec<tvq::checkpoint::Checkpoint>) = todo!();
//! // Fit the zoo into 2 MiB of registry file, bits allocated by
//! // sensitivity (the budget is total file bytes, index included).
//! let (plan, summary) = build_planned_registry(
//!     &pre, &fts, 2 << 20, &PlannerConfig::default(), "zoo.qtvc")?;
//! assert!(summary.file_bytes <= 2 << 20);
//! println!("{} B, total SSE {:.3e}", summary.file_bytes, plan.total_error());
//!
//! // Serve: group sections feed the fused dequant-merge kernel layout.
//! let reg = Registry::open("zoo.qtvc")?;
//! let merged = fused_merge(&reg, &pre, &vec![0.3; plan.n_tasks()], None, &ExecCtx::default())?;
//! // Or through the generic source / ModelCache path:
//! let _src = PackedRegistrySource::open("zoo.qtvc")?;
//! # let _ = merged; Ok(()) }
//! ```

pub mod plan;
pub mod sensitivity;
pub mod solve;

pub use plan::{Arm, Assignment, PackPlan, PlanTensor, SectionRole, SectionSpec};
pub use sensitivity::{probe, probe_with_pool, ArmStat, SensitivityProfile, TensorProfile};
pub use solve::{min_feasible_bytes, solve};

use anyhow::{bail, Context, Result};

use crate::checkpoint::Checkpoint;
use crate::obs;
use crate::quant::{BinarySwitch, GroupQuantized, SparseGroupQuantized};
use crate::registry::{
    PayloadView, PlannedSectionSource, Registry, RegistryBuilder, SectionScratch, WriteSummary,
};
use crate::tensor::Tensor;
use crate::util::exec::ExecCtx;
use crate::util::pool::Pool;
use crate::util::rng::Rng;

/// Candidate-arm configuration for the probe + solver.
#[derive(Clone, Debug)]
pub struct PlannerConfig {
    /// Per-group quantization width (clamped per tensor to its numel).
    /// Larger groups cost less scale/zp metadata; smaller groups adapt
    /// better to local ranges.
    pub group: usize,
    /// Per-task group-quantization candidate widths.
    pub tvq_bits: Vec<u8>,
    /// Shared-base/offset candidate splits `(base_bits, offset_bits)`.
    pub rtvq_arms: Vec<(u8, u8)>,
    /// DARE sparsify-then-quantize candidates `(drop_pct, bits)`: drop a
    /// deterministic pseudo-random `drop_pct`% of each task's entries,
    /// rescale survivors by `dense/kept`, group-quantize at `bits`
    /// (arXiv 2402.09997 applied as a storage arm).
    pub dare_arms: Vec<(u8, u8)>,
    /// TALL-mask-localized candidates `(keep_pct, bits)`: keep, per task,
    /// the `keep_pct`% of entries with the highest task-localization
    /// score against the multi-task vector; masked-out weights cost 0
    /// bits (arXiv 2405.07813 applied as a storage arm).
    pub tall_arms: Vec<(u8, u8)>,
    /// 1-bit binary-switch candidates, one per scale granularity:
    /// `false` = per-group scales, `true` = one per-tensor scale
    /// (1bit-Merging, arXiv 2502.10743; Binary Task Switch,
    /// arXiv 2412.00054 — applied as a storage arm).  The cheapest arm
    /// in the frontier and the payload the dynamic-merge path flips per
    /// request.
    pub onebit_arms: Vec<bool>,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        Self {
            group: 512,
            tvq_bits: vec![1, 2, 3, 4, 5, 6, 8],
            rtvq_arms: vec![(2, 1), (3, 1), (2, 2), (3, 2), (4, 2), (4, 3)],
            dare_arms: vec![(90, 4), (75, 3), (50, 2)],
            tall_arms: vec![(50, 2), (50, 3), (25, 3), (25, 4), (12, 4)],
            onebit_arms: vec![false, true],
        }
    }
}

impl PlannerConfig {
    /// The default candidate set restricted to the dense (TVQ / RTVQ)
    /// families — the PR-2 planner, used as the comparison baseline in
    /// `tabP` and the sparse-frontier tests.
    pub fn dense_only() -> Self {
        Self {
            dare_arms: Vec::new(),
            tall_arms: Vec::new(),
            onebit_arms: Vec::new(),
            ..Self::default()
        }
    }

    pub fn check(&self) -> Result<()> {
        if self.group == 0 {
            bail!("planner group width must be >= 1");
        }
        if self.tvq_bits.is_empty()
            && self.rtvq_arms.is_empty()
            && self.dare_arms.is_empty()
            && self.tall_arms.is_empty()
            && self.onebit_arms.is_empty()
        {
            bail!("planner needs at least one candidate arm");
        }
        if self.onebit_arms.len() > 2 {
            bail!("onebit candidates repeat a scale granularity (at most [false, true])");
        }
        if self.onebit_arms.len() == 2 && self.onebit_arms[0] == self.onebit_arms[1] {
            bail!("onebit candidates repeat a scale granularity (at most [false, true])");
        }
        for &b in &self.tvq_bits {
            if !(1..=8).contains(&b) {
                bail!("tvq candidate bits {b} outside 1..=8");
            }
        }
        for &(bb, bo) in &self.rtvq_arms {
            if !(1..=8).contains(&bb) || !(1..=8).contains(&bo) {
                bail!("rtvq candidate ({bb},{bo}) outside 1..=8");
            }
        }
        for &(p, b) in self.dare_arms.iter().chain(&self.tall_arms) {
            if !(1..=99).contains(&p) {
                bail!("sparse candidate percentage {p} outside 1..=99");
            }
            if !(1..=8).contains(&b) {
                bail!("sparse candidate bits {b} outside 1..=8");
            }
        }
        Ok(())
    }
}

/// Probe + solve: produce a [`PackPlan`] for the suite under
/// `budget_bytes` total registry file bytes.  The probe fans out per
/// tensor across the shared [`Pool`]; the solver is sequential (its
/// greedy order is the algorithm).
pub fn plan_pack(
    pre: &Checkpoint,
    fts: &[Checkpoint],
    budget_bytes: u64,
    cfg: &PlannerConfig,
) -> Result<PackPlan> {
    plan_pack_with_pool(pre, fts, budget_bytes, cfg, Pool::global())
}

/// [`plan_pack`] on an explicit pool (thread-scaling benches and the
/// determinism suite pin thread counts through this).
pub fn plan_pack_with_pool(
    pre: &Checkpoint,
    fts: &[Checkpoint],
    budget_bytes: u64,
    cfg: &PlannerConfig,
    pool: &Pool,
) -> Result<PackPlan> {
    let profile = sensitivity::probe_with_pool(pre, fts, cfg, pool)?;
    solve(&profile, budget_bytes)
}

/// Flatten one tensor of `ck`, zero-padded to `padded` elements — shared
/// by the probe, the writer, and the fused serve path so all three see
/// byte-identical flat layouts (the plan's cost/error model depends on
/// that agreement).  Refuses to *clip*: data longer than `padded` means
/// the caller's shape bookkeeping is wrong.
pub(crate) fn padded_flat(ck: &Checkpoint, name: &str, padded: usize) -> Result<Vec<f32>> {
    let t = ck.get(name)?;
    if t.numel() > padded {
        bail!(
            "tensor {name:?} has {} elements but the plan allots {padded} — \
             stale plan for this checkpoint?",
            t.numel()
        );
    }
    let mut flat = Vec::with_capacity(padded);
    flat.extend_from_slice(t.data());
    flat.resize(padded, 0.0);
    Ok(flat)
}

/// Multi-task flat of `tensor`: the sum of every task's padded flat
/// (tau_mtl at layer granularity) — what the TALL localization score is
/// computed against.  Shared by the probe and the writer.
pub(crate) fn sum_flat(taus: &[Checkpoint], tensor: &PlanTensor) -> Result<Vec<f32>> {
    let padded = tensor.padded();
    let mut acc = vec![0.0f32; padded];
    for tau in taus {
        let flat = padded_flat(tau, &tensor.name, padded)?;
        for (b, x) in acc.iter_mut().zip(flat) {
            *b += x;
        }
    }
    Ok(acc)
}

/// Task-mean flat of `tensor` across `taus` (theta_ft_avg - theta_pre at
/// layer granularity) — the base the RTVQ arms decompose against.
/// Shared by the probe and the writer so the plan's probed errors stay
/// bit-for-bit representative of what gets packed.
pub(crate) fn mean_flat(taus: &[Checkpoint], tensor: &PlanTensor) -> Result<Vec<f32>> {
    let mut base = sum_flat(taus, tensor)?;
    let inv = 1.0 / taus.len() as f32;
    for b in base.iter_mut() {
        *b *= inv;
    }
    Ok(base)
}

/// Deterministic DARE drop mask: exactly `k` survivor indices out of
/// `0..padded`, chosen by a seeded partial Fisher-Yates and returned in
/// ascending order.  The seed derives from (tensor name, task index,
/// drop rate) alone, so the probe and the writer — and any re-pack of the
/// same suite — produce bit-identical masks.
pub(crate) fn dare_keep_indices(
    tensor_name: &str,
    task: usize,
    drop_pct: u8,
    padded: usize,
    k: usize,
) -> Vec<usize> {
    // FNV-1a over the tensor name, mixed with task index + drop rate.
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in tensor_name.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    let seed = h
        ^ (task as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ ((drop_pct as u64) << 56);
    let mut rng = Rng::new(seed);
    let mut idx: Vec<u32> = (0..padded as u32).collect();
    for i in 0..k {
        let j = i + rng.below(padded - i);
        idx.swap(i, j);
    }
    let mut keep: Vec<usize> = idx[..k].iter().map(|&i| i as usize).collect();
    keep.sort_unstable();
    keep
}

/// TALL-mask keep set: the `k` indices with the highest localization
/// score `|tau_t[i]| / (|tau_mtl[i] - tau_t[i]| + eps)` — sweeping k walks
/// the same family TALL's lambda threshold does (the k-th score is the
/// implied lambda).  Ties break by index; returned ascending.
pub(crate) fn tall_keep_indices(flat: &[f32], mtl: &[f32], k: usize) -> Vec<usize> {
    debug_assert_eq!(flat.len(), mtl.len());
    debug_assert!(k >= 1 && k <= flat.len());
    let score = |i: usize| {
        let rest = (mtl[i] - flat[i]).abs();
        flat[i].abs() / (rest + 1e-12)
    };
    let mut idx: Vec<usize> = (0..flat.len()).collect();
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        score(b).total_cmp(&score(a)).then(a.cmp(&b))
    });
    let mut keep = idx[..k].to_vec();
    keep.sort_unstable();
    keep
}

/// Build the kind-4 sparse payload for one `(arm, tensor, task)` slot —
/// the single code path the probe measures and the writer packs, so the
/// plan's probed error and byte cost are exact for the written file.
/// `mtl` is the multi-task flat, required for TALL arms.
pub(crate) fn sparse_section(
    arm: Arm,
    tensor: &PlanTensor,
    task: usize,
    flat: &[f32],
    mtl: Option<&[f32]>,
) -> Result<SparseGroupQuantized> {
    let padded = tensor.padded();
    debug_assert_eq!(flat.len(), padded);
    let k = arm
        .survivors(padded)
        .ok_or_else(|| anyhow::anyhow!("dense arm {} has no sparse section", arm.label()))?;
    let (keep, bits) = match arm {
        Arm::Dare { drop_pct, bits } => {
            (dare_keep_indices(&tensor.name, task, drop_pct, padded, k), bits)
        }
        Arm::Tall { bits, .. } => {
            let mtl = mtl.ok_or_else(|| {
                anyhow::anyhow!("TALL arm needs the multi-task vector")
            })?;
            (tall_keep_indices(flat, mtl, k), bits)
        }
        _ => unreachable!("survivors() returned Some for a dense arm"),
    };
    SparseGroupQuantized::quantize_indices(flat, &keep, arm.rescale(padded, k), bits, tensor.group)
}

/// Build the kind-5 binary payload for one `(arm, tensor)` slot — the
/// single code path the probe measures and the writer packs, so the
/// plan's probed error and byte cost are exact for the written file.
pub(crate) fn binary_section(arm: Arm, tensor: &PlanTensor, flat: &[f32]) -> Result<BinarySwitch> {
    let padded = tensor.padded();
    debug_assert_eq!(flat.len(), padded);
    let group = arm
        .binary_group(padded, tensor.group)
        .ok_or_else(|| anyhow::anyhow!("non-binary arm {} has no binary section", arm.label()))?;
    BinarySwitch::quantize(flat, group)
}

/// Quantize `flat - base_hat` at `bits` — the error-corrected RTVQ
/// offset (paper Eq. 6: the base's quantization error is folded into
/// what the offset sees).  Shared by the probe and the writer.
pub(crate) fn quantize_offset(
    flat: &[f32],
    base_hat: &[f32],
    bits: u8,
    group: usize,
) -> Result<GroupQuantized> {
    let off: Vec<f32> = flat.iter().zip(base_hat).map(|(&x, &b)| x - b).collect();
    GroupQuantized::quantize(&off, bits, group)
}

/// Compile `plan` against the suite into a `QTVC` v3 (dense arms), v4
/// (sparse arms) or v5 (binary arms) registry at `path`.
///
/// Quantization is re-derived deterministically from the same inputs the
/// probe saw, so the written file's size equals
/// [`PackPlan::planned_file_bytes`] **exactly** — the function errors if
/// it does not, because that would mean the solver optimized a different
/// file than the writer produced.
///
/// Per-slot quantization fans out across the shared [`Pool`]; sections
/// are handed to the builder in the fixed (base, then `(task, tensor)`)
/// index order regardless of completion order, so the written bytes are
/// identical at every thread count.
pub fn write_planned_registry<P: AsRef<std::path::Path>>(
    pre: &Checkpoint,
    fts: &[Checkpoint],
    plan: &PackPlan,
    path: P,
) -> Result<WriteSummary> {
    write_planned_registry_with_pool(pre, fts, plan, path, Pool::global())
}

/// [`write_planned_registry`] on an explicit pool.
pub fn write_planned_registry_with_pool<P: AsRef<std::path::Path>>(
    pre: &Checkpoint,
    fts: &[Checkpoint],
    plan: &PackPlan,
    path: P,
    pool: &Pool,
) -> Result<WriteSummary> {
    plan.validate()?;
    if fts.len() != plan.n_tasks() {
        bail!(
            "plan covers {} tasks but {} checkpoints were supplied",
            plan.n_tasks(),
            fts.len()
        );
    }
    if pre.len() != plan.n_tensors() {
        bail!(
            "trunk has {} tensors but the plan covers {} — stale plan for \
             this zoo?",
            pre.len(),
            plan.n_tensors()
        );
    }
    // Per-tensor shape match, not just count: a same-count zoo with
    // resized layers must fail here, never pack truncated/zero-padded
    // task vectors that CRC-verify clean.
    for tensor in &plan.tensors {
        let t = pre.get(&tensor.name)?;
        if t.shape() != &tensor.shape[..] {
            bail!(
                "tensor {:?}: trunk shape {:?} does not match plan shape {:?} — \
                 stale plan for this zoo?",
                tensor.name,
                t.shape(),
                tensor.shape
            );
        }
    }
    let taus: Vec<Checkpoint> = fts.iter().map(|ft| ft.sub(pre)).collect::<Result<_>>()?;

    let mut builder = RegistryBuilder::new_planned();
    builder.set_plan(plan)?;
    // Bases first (tensor order), then task sections in (task, tensor)
    // order — the same deterministic layout the cost model priced, built
    // from the same shared helpers the probe measured with.  RTVQ-arm
    // tensors need their dequantized base; TALL-arm tensors need the
    // multi-task vector the localization mask scores against.  Both
    // phases fan the quantization work out across the pool; section
    // insertion stays a sequential walk in slot-index order, so the
    // on-disk layout never depends on worker completion order.
    struct TensorAux {
        qbase: Option<GroupQuantized>,
        base_hat: Option<Vec<f32>>,
        mtl: Option<Vec<f32>>,
    }
    let aux: Vec<TensorAux> = pool.try_map(
        plan.tensors.iter().zip(&plan.assignments).collect(),
        |_, (tensor, a): (&PlanTensor, &Assignment)| {
            Ok(match a.arm {
                Arm::Rtvq { base_bits, .. } => {
                    let base = mean_flat(&taus, tensor)?;
                    let qbase = GroupQuantized::quantize(&base, base_bits, tensor.group)?;
                    let base_hat = Some(qbase.dequantize());
                    TensorAux { qbase: Some(qbase), base_hat, mtl: None }
                }
                Arm::Tall { .. } => TensorAux {
                    qbase: None,
                    base_hat: None,
                    mtl: Some(sum_flat(&taus, tensor)?),
                },
                Arm::Tvq { .. } | Arm::Dare { .. } | Arm::OneBit { .. } => {
                    TensorAux { qbase: None, base_hat: None, mtl: None }
                }
            })
        },
    )?;
    for (tensor, a) in plan.tensors.iter().zip(&aux) {
        if let Some(qbase) = &a.qbase {
            builder.add_group(&plan::base_section_name(&tensor.name), qbase)?;
        }
    }
    enum Section {
        Group(GroupQuantized),
        Sparse(SparseGroupQuantized),
        Binary(BinarySwitch),
    }
    let slots: Vec<(usize, usize)> = (0..plan.n_tasks())
        .flat_map(|t| (0..plan.n_tensors()).map(move |l| (t, l)))
        .collect();
    let sections: Vec<Section> = pool.try_map(slots, |_, (t, l)| {
        let tensor = &plan.tensors[l];
        let a = &plan.assignments[l];
        let flat = padded_flat(&taus[t], &tensor.name, tensor.padded())?;
        Ok(match a.arm {
            Arm::Tvq { bits } => {
                Section::Group(GroupQuantized::quantize(&flat, bits, tensor.group)?)
            }
            Arm::Rtvq { offset_bits, .. } => {
                let base_hat =
                    aux[l].base_hat.as_ref().expect("base quantized above for rtvq arms");
                Section::Group(quantize_offset(&flat, base_hat, offset_bits, tensor.group)?)
            }
            Arm::Dare { .. } | Arm::Tall { .. } => {
                Section::Sparse(sparse_section(a.arm, tensor, t, &flat, aux[l].mtl.as_deref())?)
            }
            Arm::OneBit { .. } => Section::Binary(binary_section(a.arm, tensor, &flat)?),
        })
    })?;
    // Consume the sections as they are encoded: the builder holds its
    // own encoded copy, so dropping each quantized payload here keeps
    // peak memory at ~one payload set, not two.
    for (i, section) in sections.into_iter().enumerate() {
        let (t, l) = (i / plan.n_tensors(), i % plan.n_tensors());
        let name = plan::task_section_name(&plan.task_names[t], &plan.tensors[l].name);
        match section {
            Section::Group(g) => builder.add_group(&name, &g)?,
            Section::Sparse(s) => builder.add_sparse(&name, &s)?,
            Section::Binary(b) => builder.add_binary(&name, &b)?,
        };
    }
    let summary = builder.write(path)?;
    if summary.file_bytes != plan.planned_file_bytes() {
        bail!(
            "planned registry measured {} B but the plan predicted {} B — \
             cost model and writer disagree",
            summary.file_bytes,
            plan.planned_file_bytes()
        );
    }
    Ok(summary)
}

/// One-call path: probe, solve under `budget_bytes`, and write the
/// planned registry to `path`.
pub fn build_planned_registry<P: AsRef<std::path::Path>>(
    pre: &Checkpoint,
    fts: &[Checkpoint],
    budget_bytes: u64,
    cfg: &PlannerConfig,
    path: P,
) -> Result<(PackPlan, WriteSummary)> {
    let plan = plan_pack(pre, fts, budget_bytes, cfg)?;
    let summary = write_planned_registry(pre, fts, &plan, path)?;
    Ok((plan, summary))
}

/// Fused dequantize-and-merge straight from a planned registry's payload
/// sections: `theta_pre + sum_t lams[t] * tau_hat_t`, tensor by tensor,
/// without materializing any per-task f32 task vector — and, under
/// `IoMode::Mmap`, without copying a single payload byte: every section
/// is decoded as a borrowed view ([`Registry::planned_task_view`]) and
/// dequantized straight out of the file mapping.
///
/// `tasks` selects a subset (all tasks when `None`); `lams` must have one
/// coefficient per *selected* task.  TVQ-arm tensors accumulate per task
/// through [`GroupQuantizedView::axpy_groups_into`](crate::quant::GroupQuantizedView::axpy_groups_into)
/// (the same fused loop
/// [`dequant_merge_flat`](crate::quant::fused::dequant_merge_flat) runs
/// over owned payloads); RTVQ-arm tensors fold the shared base in once
/// scaled by `sum(lams)` first (the
/// [`dequant_merge_rtvq_flat`](crate::quant::fused::dequant_merge_rtvq_flat)
/// order); sparse-arm (DARE / TALL) tensors scatter-accumulate only their
/// survivors — masked-out weights never touch the accumulator; binary-arm
/// (OneBit) tensors accumulate `lam * (±scale)` per element straight off
/// the sign bitmap
/// ([`BinarySwitchView::axpy_range_into`](crate::quant::BinarySwitchView::axpy_range_into)).
///
/// # Parallelism and determinism
///
/// Each tensor's accumulator is sharded over **disjoint output ranges**
/// (group-aligned for dense arms, mask-byte-aligned for sparse arms)
/// across the shared [`Pool`]: every shard replays the full per-task
/// axpy sequence over its own range, so each output element sees exactly
/// the accumulation order of the sequential pass — merged floats are
/// bit-identical at every thread count (no atomics-ordered reductions
/// anywhere).  Section views are decoded and CRC-checked once per
/// (task, tensor), exactly as often as the sequential path.  Tensors
/// under 32Ki elements skip the worker spawn and run inline — the same
/// shard math over the full range, so the cutoff never changes results.
///
/// # Execution
///
/// The [`ExecCtx`] selects the pool (`ExecCtx::sequential()` is the
/// bit-exact reference path the determinism suite compares against),
/// the SIMD kernel the inner loops dispatch over (every kernel is
/// bit-identical to scalar — see [`crate::quant::simd`]), and an
/// optional trace label; `reg` is any [`PlannedSectionSource`] — the
/// monolithic [`Registry`] and the sharded
/// [`ShardedRegistry`](crate::registry::ShardedRegistry) (tier 0 or
/// tier 1) produce bit-identical merges through this one body.
pub fn fused_merge<S: PlannedSectionSource + ?Sized>(
    reg: &S,
    pre: &Checkpoint,
    lams: &[f32],
    tasks: Option<&[usize]>,
    ctx: &ExecCtx,
) -> Result<Checkpoint> {
    let _op = ctx.op_span(obs::Category::Merge);
    let pool = ctx.pool();
    let kern = ctx.kernel();
    let plan = reg
        .pack_plan()
        .context("fused_merge needs a planned (PLAN-MIXED) registry")?;
    let indices: Vec<usize> = match tasks {
        Some(ts) => {
            for &t in ts {
                if t >= plan.n_tasks() {
                    bail!("task index {t} out of range ({} tasks)", plan.n_tasks());
                }
            }
            ts.to_vec()
        }
        None => (0..plan.n_tasks()).collect(),
    };
    if indices.is_empty() {
        bail!("merge needs at least one task");
    }
    if lams.len() != indices.len() {
        bail!("{} lambdas for {} selected tasks", lams.len(), indices.len());
    }
    // The plan must cover the trunk exactly — a trunk with tensors the
    // plan never saw would otherwise come back silently truncated
    // (the generic merge path errors on the same mismatch).
    if pre.len() != plan.n_tensors() {
        bail!(
            "pre-trained trunk has {} tensors but the plan covers {} — wrong \
             trunk for this registry?",
            pre.len(),
            plan.n_tensors()
        );
    }

    let mut out = Checkpoint::new();
    // One section scratch per selected task (plus one for the shared
    // base): under IoMode::Mmap they stay empty (views borrow the file
    // mapping); under Pread/Reopen each stages its own section so every
    // view for a tensor can be live at once while the shards run.
    let mut scratches: Vec<SectionScratch> =
        (0..indices.len() + 1).map(|_| SectionScratch::default()).collect();
    // Tensors below this size run their single shard inline: the scoped
    // spawn+join of a worker set costs more than decoding a small
    // accumulator, and the pool is re-scoped per tensor.  Purely a
    // latency heuristic — shard math is identical, so results are
    // bit-exact on either path.
    const MIN_PARALLEL_ELEMS: usize = 1 << 15;
    let seq = Pool::sequential();
    for (l, (tensor, a)) in plan.tensors.iter().zip(&plan.assignments).enumerate() {
        let pre_t = pre.get(&tensor.name)?;
        if pre_t.numel() != tensor.numel() || pre_t.shape() != &tensor.shape[..] {
            bail!(
                "pre-trained tensor {:?} shape {:?} does not match plan shape {:?}",
                tensor.name,
                pre_t.shape(),
                tensor.shape
            );
        }
        let mut buf = padded_flat(pre, &tensor.name, tensor.padded())?;
        // Decode + CRC-check every selected view once per tensor, then
        // shard the accumulator; shards replay the same per-task order
        // over disjoint ranges, so every element's float accumulation
        // chain equals the sequential pass exactly.
        let (base_scratch, task_scratches) = scratches.split_first_mut().expect("len >= 1");
        let decode_span = obs::span(obs::Category::Merge, "view_decode").with_arg("tensor", l as u64);
        let views: Vec<PayloadView> = indices
            .iter()
            .zip(task_scratches.iter_mut())
            .map(|(&t, s)| reg.planned_task_view(t, l, s))
            .collect::<Result<_>>()?;
        drop(decode_span);
        let pool = if buf.len() < MIN_PARALLEL_ELEMS { &seq } else { pool };
        let axpy_span = obs::span(obs::Category::Merge, "axpy").with_arg("tensor", l as u64);
        match a.arm {
            Arm::Tvq { .. } => {
                pool.for_each_shard(&mut buf, tensor.group, |start, shard| {
                    let mut codes: Vec<u32> = Vec::new();
                    let g0 = start / tensor.group;
                    for (view, &lam) in views.iter().zip(lams) {
                        view.as_group()?.axpy_groups_into_k(kern, lam, g0, shard, &mut codes)?;
                    }
                    Ok(())
                })?;
            }
            Arm::Rtvq { .. } => {
                // Base first, scaled by sum(lams) — the same accumulation
                // order dequant_merge_rtvq_flat uses — then the offsets.
                let lam_sum: f32 = lams.iter().sum();
                let base = reg.planned_base_view(l, base_scratch)?;
                pool.for_each_shard(&mut buf, tensor.group, |start, shard| {
                    let mut codes: Vec<u32> = Vec::new();
                    let g0 = start / tensor.group;
                    base.axpy_groups_into_k(kern, lam_sum, g0, shard, &mut codes)?;
                    for (view, &lam) in views.iter().zip(lams) {
                        view.as_group()?.axpy_groups_into_k(kern, lam, g0, shard, &mut codes)?;
                    }
                    Ok(())
                })?;
            }
            Arm::Dare { .. } | Arm::Tall { .. } => {
                pool.for_each_shard(&mut buf, 8, |start, shard| {
                    let (mut codes, mut vals) = (Vec::new(), Vec::new());
                    let byte0 = start / 8;
                    for (view, &lam) in views.iter().zip(lams) {
                        view.as_sparse()?
                            .axpy_range_into_k(kern, lam, byte0, shard, &mut codes, &mut vals);
                    }
                    Ok(())
                })?;
            }
            Arm::OneBit { .. } => {
                // Sign-byte-aligned shards: each element's increment is
                // lam * scale(g) computed identically in every shard.
                pool.for_each_shard(&mut buf, 8, |start, shard| {
                    let byte0 = start / 8;
                    for (view, &lam) in views.iter().zip(lams) {
                        view.as_binary()?.axpy_range_into_k(kern, lam, byte0, shard);
                    }
                    Ok(())
                })?;
            }
        }
        drop(axpy_span);
        drop(views);
        buf.truncate(tensor.numel());
        out.insert(&tensor.name, Tensor::new(tensor.shape.clone(), buf)?);
    }
    Ok(out)
}

/// [`fused_merge`] on an explicit pool — the PR-5 twin, superseded by
/// [`ExecCtx`].
#[deprecated(note = "use fused_merge(reg, pre, lams, tasks, &ExecCtx::with_pool(pool))")]
pub fn fused_merge_with_pool(
    reg: &Registry,
    pre: &Checkpoint,
    lams: &[f32],
    tasks: Option<&[usize]>,
    pool: &Pool,
) -> Result<Checkpoint> {
    fused_merge(reg, pre, lams, tasks, &ExecCtx::with_pool(pool))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Heterogeneous suite: per-layer tau scales spanning 25x, the regime
    /// where mixed precision pays.
    pub(crate) fn hetero_suite(n_tasks: usize, seed: u64) -> (Checkpoint, Vec<Checkpoint>) {
        let mut rng = Rng::new(seed);
        let stds = [0.002f32, 0.005, 0.02, 0.05];
        let mut pre = Checkpoint::new();
        for (i, _) in stds.iter().enumerate() {
            pre.insert(&format!("blk{i:02}/w"), Tensor::randn(&[64, 48], 0.3, &mut rng));
        }
        let mut drift = Checkpoint::new();
        for (i, &std) in stds.iter().enumerate() {
            drift.insert(&format!("blk{i:02}/w"), Tensor::randn(&[64, 48], std, &mut rng));
        }
        let fts = (0..n_tasks)
            .map(|_| {
                let mut off = Checkpoint::new();
                for (i, &std) in stds.iter().enumerate() {
                    off.insert(
                        &format!("blk{i:02}/w"),
                        Tensor::randn(&[64, 48], std * 0.3, &mut rng),
                    );
                }
                pre.add(&drift).unwrap().add(&off).unwrap()
            })
            .collect();
        (pre, fts)
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("tvq_planner_{name}"))
    }

    fn small_cfg() -> PlannerConfig {
        PlannerConfig {
            group: 256,
            tvq_bits: vec![1, 2, 3, 4, 6],
            rtvq_arms: vec![(3, 1), (3, 2), (4, 2)],
            dare_arms: vec![],
            tall_arms: vec![],
            onebit_arms: vec![],
        }
    }

    #[test]
    fn plan_writes_byte_exact_registry() {
        let (pre, fts) = hetero_suite(4, 21);
        let cfg = small_cfg();
        let profile = probe(&pre, &fts, &cfg).unwrap();
        let budget = min_feasible_bytes(&profile) * 2;
        let dir = tmp("exact");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("zoo.qtvc");
        let (plan, summary) =
            build_planned_registry(&pre, &fts, budget, &cfg, &path).unwrap();
        assert!(plan.planned_file_bytes() <= budget);
        assert_eq!(summary.file_bytes, plan.planned_file_bytes());
        assert_eq!(summary.file_bytes, std::fs::metadata(&path).unwrap().len());
        assert_eq!(summary.n_tasks, 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mixed_allocation_is_uneven_across_heterogeneous_layers() {
        let (pre, fts) = hetero_suite(4, 22);
        let cfg = small_cfg();
        let profile = probe(&pre, &fts, &cfg).unwrap();
        // A mid-range budget forces a choice.
        let min = min_feasible_bytes(&profile);
        let plan = solve(&profile, min + (min / 2)).unwrap();
        let bits_of = |a: &Assignment| match a.arm {
            Arm::Tvq { bits } => bits,
            Arm::Rtvq { offset_bits, .. } => offset_bits,
            Arm::Dare { bits, .. } | Arm::Tall { bits, .. } => bits,
            Arm::OneBit { .. } => 1,
        };
        let quiet = bits_of(&plan.assignments[0]); // std 0.002
        let loud = bits_of(&plan.assignments[3]); // std 0.05
        assert!(
            loud >= quiet,
            "louder layer got fewer offset bits: loud={loud} quiet={quiet}"
        );
        // Across the sweep some pair must differ, else it's not mixed.
        let all: Vec<u8> = plan.assignments.iter().map(bits_of).collect();
        assert!(all.iter().any(|&b| b != all[0]), "allocation is uniform: {all:?}");
    }

    #[test]
    fn fused_merge_matches_task_vector_reconstruction() {
        let (pre, fts) = hetero_suite(4, 23);
        let cfg = small_cfg();
        let dir = tmp("fused");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("zoo.qtvc");
        let profile = probe(&pre, &fts, &cfg).unwrap();
        let budget = min_feasible_bytes(&profile) * 2;
        build_planned_registry(&pre, &fts, budget, &cfg, &path).unwrap();
        let reg = Registry::open(&path).unwrap();

        // Reference: pre + sum lam * tau_hat from the generic lazy path.
        let lams = [0.4f32, 0.1, 0.3, 0.2];
        let mut want = pre.clone();
        for (t, &lam) in lams.iter().enumerate() {
            want.axpy(lam, &reg.load_task_vector(t, &ExecCtx::sequential()).unwrap()).unwrap();
        }
        let got = fused_merge(&reg, &pre, &lams, None, &ExecCtx::default()).unwrap();
        assert!(
            got.l2_dist(&want).unwrap() < 1e-4,
            "fused path diverged: {}",
            got.l2_dist(&want).unwrap()
        );

        // Subset selection with mismatched lambda count is rejected.
        assert!(fused_merge(&reg, &pre, &lams, Some(&[0, 2]), &ExecCtx::default()).is_err());
        let sub = fused_merge(&reg, &pre, &[0.4, 0.3], Some(&[0, 2]), &ExecCtx::default()).unwrap();
        let mut want_sub = pre.clone();
        want_sub.axpy(0.4, &reg.load_task_vector(0, &ExecCtx::sequential()).unwrap()).unwrap();
        want_sub.axpy(0.3, &reg.load_task_vector(2, &ExecCtx::sequential()).unwrap()).unwrap();
        assert!(sub.l2_dist(&want_sub).unwrap() < 1e-4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mask_helpers_are_deterministic_and_well_formed() {
        // DARE: same (name, task, rate) -> same mask; different task ->
        // different mask (overwhelmingly); indices ascending and unique.
        let a = dare_keep_indices("blk00/w", 0, 90, 512, 52);
        let b = dare_keep_indices("blk00/w", 0, 90, 512, 52);
        assert_eq!(a, b, "dare mask must be deterministic");
        assert_eq!(a.len(), 52);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "ascending + unique");
        assert!(*a.last().unwrap() < 512);
        let c = dare_keep_indices("blk00/w", 1, 90, 512, 52);
        assert_ne!(a, c, "different tasks must get different masks");

        // TALL: the top-k by |tau|/|mtl - tau| are kept.
        let flat = [0.0f32, 5.0, 0.1, -4.0, 0.2, 0.0];
        let mtl = [1.0f32, 5.5, 3.0, -4.1, 0.25, 0.0];
        let keep = tall_keep_indices(&flat, &mtl, 3);
        // Scores: idx1 = 5/0.5 = 10, idx3 = 4/0.1 = 40, idx4 = 0.2/0.05 = 4.
        assert_eq!(keep, vec![1, 3, 4]);
        assert_eq!(tall_keep_indices(&flat, &mtl, 1), vec![3]);
    }

    #[test]
    fn sparse_plan_roundtrips_byte_exact_through_registry() {
        let (pre, fts) = hetero_suite(3, 25);
        // Force sparse arms everywhere: the candidate set has no dense arm.
        let cfg = PlannerConfig {
            group: 256,
            tvq_bits: vec![],
            rtvq_arms: vec![],
            dare_arms: vec![(75, 3)],
            tall_arms: vec![(25, 4), (50, 2)],
            onebit_arms: vec![],
        };
        let profile = probe(&pre, &fts, &cfg).unwrap();
        let budget = min_feasible_bytes(&profile) * 2;
        let dir = tmp("sparse_exact");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("zoo.qtvc");
        let (plan, summary) = build_planned_registry(&pre, &fts, budget, &cfg, &path).unwrap();
        assert!(plan.has_sparse_arms());
        assert_eq!(summary.file_bytes, plan.planned_file_bytes());
        assert_eq!(summary.file_bytes, std::fs::metadata(&path).unwrap().len());

        // The registry reopens as v4 with the same plan, and the fused
        // path agrees with the lazy reconstruction path.
        let reg = Registry::open(&path).unwrap();
        assert_eq!(reg.version(), 4);
        assert_eq!(reg.plan().unwrap(), &plan);
        let lams = [0.5f32, 0.2, 0.3];
        let mut want = pre.clone();
        for (t, &lam) in lams.iter().enumerate() {
            want.axpy(lam, &reg.load_task_vector(t, &ExecCtx::sequential()).unwrap()).unwrap();
        }
        let got = fused_merge(&reg, &pre, &lams, None, &ExecCtx::default()).unwrap();
        assert!(
            got.l2_dist(&want).unwrap() < 1e-4,
            "sparse fused path diverged: {}",
            got.l2_dist(&want).unwrap()
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn onebit_plan_roundtrips_byte_exact_through_registry() {
        let (pre, fts) = hetero_suite(3, 26);
        // Force binary arms everywhere: the candidate set has nothing else.
        let cfg = PlannerConfig {
            group: 256,
            tvq_bits: vec![],
            rtvq_arms: vec![],
            dare_arms: vec![],
            tall_arms: vec![],
            onebit_arms: vec![false, true],
        };
        let profile = probe(&pre, &fts, &cfg).unwrap();
        let budget = min_feasible_bytes(&profile) * 2;
        let dir = tmp("onebit_exact");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("zoo.qtvc");
        let (plan, summary) = build_planned_registry(&pre, &fts, budget, &cfg, &path).unwrap();
        assert!(plan.has_onebit_arms());
        assert_eq!(summary.file_bytes, plan.planned_file_bytes());
        assert_eq!(summary.file_bytes, std::fs::metadata(&path).unwrap().len());

        // The registry reopens as v5 with the same plan, and the fused
        // path agrees with the lazy reconstruction path bit-for-bit
        // (both reconstruct the same ±scale values).
        let reg = Registry::open(&path).unwrap();
        assert_eq!(reg.version(), 5);
        assert_eq!(reg.plan().unwrap(), &plan);
        let lams = [0.5f32, 0.2, 0.3];
        let mut want = pre.clone();
        for (t, &lam) in lams.iter().enumerate() {
            want.axpy(lam, &reg.load_task_vector(t, &ExecCtx::sequential()).unwrap()).unwrap();
        }
        let got = fused_merge(&reg, &pre, &lams, None, &ExecCtx::default()).unwrap();
        assert!(
            got.l2_dist(&want).unwrap() < 1e-4,
            "binary fused path diverged: {}",
            got.l2_dist(&want).unwrap()
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn plan_task_count_mismatch_rejected() {
        let (pre, fts) = hetero_suite(3, 24);
        let cfg = small_cfg();
        let profile = probe(&pre, &fts, &cfg).unwrap();
        let plan = solve(&profile, min_feasible_bytes(&profile) * 2).unwrap();
        let dir = tmp("mismatch");
        let err = write_planned_registry(&pre, &fts[..2], &plan, dir.join("z.qtvc"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("tasks"), "got: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
