//! `tvq` — the command-line entrypoint for the TVQ merging system.
//!
//! Subcommands:
//!
//! * `train`      — build (or refresh) a checkpoint zoo via PJRT training.
//! * `quantize`   — quantize a zoo under a scheme; report storage + error.
//! * `merge`      — merge under (method, scheme) and evaluate per task.
//! * `eval`       — evaluate reconstructed single-task models (Individual).
//! * `serve`      — boot the coordinator and run a load demo.
//! * `experiment` — regenerate one of the paper's tables/figures by id.
//! * `list`       — show available artifacts, presets, experiments.

use anyhow::{anyhow, bail, Result};

use tvq::coordinator::{Server, ServerConfig, ServeModel};
use tvq::data::preset_by_name;
use tvq::exp;
use tvq::merge::{standard_methods, Merger};
use tvq::quant::QuantScheme;
use tvq::runtime::Runtime;
use tvq::tensor::Tensor;
use tvq::train::{TrainConfig, Zoo};
use tvq::util::cli::Command;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "tvq — Task Vector Quantization for memory-efficient model merging

usage: tvq <subcommand> [options]

subcommands:
  train       build/refresh a checkpoint zoo (PJRT fine-tuning)
  quantize    quantize task vectors; report storage and error
  merge       merge under a (method, scheme) and evaluate
  eval        evaluate Individual (single-task) models under a scheme
  serve       boot the serving coordinator and run a load demo
  experiment  regenerate a paper table/figure by id (tab1, fig4, ...)
  list        list presets, artifacts and experiment ids

run `tvq <subcommand> --help` for options."
        .to_string()
}

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(sub) = argv.first() else {
        println!("{}", usage());
        return Ok(());
    };
    let rest = &argv[1..];
    match sub.as_str() {
        "train" => cmd_train(rest),
        "quantize" => cmd_quantize(rest),
        "merge" => cmd_merge(rest),
        "eval" => cmd_eval(rest),
        "serve" => cmd_serve(rest),
        "experiment" => cmd_experiment(rest),
        "list" => cmd_list(),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n\n{}", usage()),
    }
}

fn zoo_args(cmd: Command) -> Command {
    cmd.opt("preset", "vit_s", "model preset (vit_s | vit_m | vit_l)")
        .opt("tasks", "8", "number of tasks in the suite")
        .opt("steps", "200", "fine-tuning steps per task")
}

fn load_zoo(args: &tvq::util::cli::Args, rt: &Runtime) -> Result<Zoo> {
    let preset = preset_by_name(args.get_str("preset")?)
        .ok_or_else(|| anyhow!("unknown preset"))?;
    let cfg = TrainConfig { steps: args.get_usize("steps")?, ..TrainConfig::default() };
    Zoo::build_or_load(rt, preset, args.get_usize("tasks")?, &cfg)
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let cmd = zoo_args(Command::new("tvq train", "build/refresh a checkpoint zoo"));
    let args = cmd.parse(argv)?;
    let rt = Runtime::new()?;
    let zoo = load_zoo(&args, &rt)?;
    println!(
        "zoo ready: preset {} | {} tasks | {} params/ckpt | {:.1} MiB fp32 total",
        zoo.preset.name,
        zoo.n_tasks(),
        zoo.pre.numel(),
        (zoo.n_tasks() * zoo.pre.fp32_bytes()) as f64 / (1024.0 * 1024.0),
    );
    Ok(())
}

fn cmd_quantize(argv: &[String]) -> Result<()> {
    let cmd = zoo_args(Command::new("tvq quantize", "quantize a zoo's task vectors"))
        .opt("scheme", "tvq3", "fp32 | fq<b> | tvq<b> | rtvq<bb>o<bo>");
    let args = cmd.parse(argv)?;
    let scheme = QuantScheme::parse(args.get_str("scheme")?)?;
    let rt = Runtime::new()?;
    let zoo = load_zoo(&args, &rt)?;
    let st = exp::scheme_taus(&zoo.pre, &zoo.fts, scheme)?;
    let taus = zoo.task_vectors()?;
    let err: f64 = taus
        .iter()
        .zip(&st.taus)
        .map(|(a, b)| a.l2_dist(b).unwrap_or(f64::NAN))
        .sum();
    let fp32 = zoo.n_tasks() * zoo.pre.fp32_bytes();
    println!(
        "{}: storage {} bytes ({:.1}% of fp32 {fp32}), total L2 error {err:.4e}, {:.3} effective bits/task",
        scheme.label(),
        st.storage_bytes,
        100.0 * st.storage_bytes as f64 / fp32 as f64,
        scheme.effective_bits(zoo.n_tasks()),
    );
    Ok(())
}

fn pick_method(name: &str) -> Result<Box<dyn Merger>> {
    standard_methods()
        .into_iter()
        .find(|m| m.name().eq_ignore_ascii_case(name))
        .ok_or_else(|| {
            anyhow!(
                "unknown method {name:?}; available: {}",
                standard_methods()
                    .iter()
                    .map(|m| m.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })
}

fn cmd_merge(argv: &[String]) -> Result<()> {
    let cmd = zoo_args(Command::new("tvq merge", "merge and evaluate"))
        .opt("scheme", "tvq3", "quantization scheme")
        .opt("method", "task_arithmetic", "merging method");
    let args = cmd.parse(argv)?;
    let scheme = QuantScheme::parse(args.get_str("scheme")?)?;
    let method = pick_method(args.get_str("method")?)?;
    let rt = Runtime::new()?;
    let zoo = load_zoo(&args, &rt)?;
    let st = exp::scheme_taus(&zoo.pre, &zoo.fts, scheme)?;
    let merged = method.merge(&zoo.pre, &st.taus)?;
    let accs = exp::classify::eval_merged(&rt, &zoo, &merged)?;
    for (t, a) in accs.iter().enumerate() {
        println!("task{t:02}: {a:.1}%");
    }
    println!(
        "{} + {}: avg accuracy {:.1}%",
        method.name(),
        scheme.label(),
        accs.iter().sum::<f64>() / accs.len() as f64
    );
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let cmd = zoo_args(Command::new("tvq eval", "evaluate Individual models"))
        .opt("scheme", "fp32", "quantization scheme");
    let args = cmd.parse(argv)?;
    let scheme = QuantScheme::parse(args.get_str("scheme")?)?;
    let rt = Runtime::new()?;
    let zoo = load_zoo(&args, &rt)?;
    let acc = exp::classify::individual_accuracy(&rt, &zoo, scheme)?;
    println!("Individual @ {}: avg accuracy {:.1}%", scheme.label(), acc);
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let cmd = zoo_args(Command::new("tvq serve", "serving-coordinator load demo"))
        .opt("scheme", "tvq3", "quantization scheme")
        .opt("method", "task_arithmetic", "merging method")
        .opt("requests", "256", "total requests to issue")
        .opt("clients", "4", "concurrent client threads")
        .opt("executors", "2", "PJRT executor threads")
        .opt("max-batch", "32", "max dynamic batch size")
        .opt("max-delay-ms", "2", "batching deadline (ms)")
        .opt("tcp", "", "serve over TCP at this address (e.g. 127.0.0.1:7070) and drive the demo load through it");
    let args = cmd.parse(argv)?;
    let scheme = QuantScheme::parse(args.get_str("scheme")?)?;
    let method = pick_method(args.get_str("method")?)?;
    let rt = Runtime::new()?;
    let zoo = load_zoo(&args, &rt)?;
    let st = exp::scheme_taus(&zoo.pre, &zoo.fts, scheme)?;
    let merged = std::sync::Arc::new(method.merge(&zoo.pre, &st.taus)?);
    let heads = std::sync::Arc::new(
        zoo.suite.tasks.iter().map(|t| t.head.clone()).collect::<Vec<_>>(),
    );
    let model = ServeModel { preset: zoo.preset, merged, heads };
    let cfg = ServerConfig {
        max_batch: args.get_usize("max-batch")?,
        max_delay: std::time::Duration::from_millis(args.get_u64("max-delay-ms")?),
        queue_cap: 4096,
        executors: args.get_usize("executors")?,
    };
    let server = std::sync::Arc::new(Server::start(cfg, model)?);
    let n_req = args.get_usize("requests")?;
    let clients = args.get_usize("clients")?.max(1);
    let per = n_req / clients;
    // Optional TCP front-end: clients go over the wire instead of the
    // in-process API (same batching/metrics path underneath).
    let tcp_addr = args.get("tcp").filter(|a| !a.is_empty()).map(String::from);
    let front = match &tcp_addr {
        Some(addr) => {
            let f = tvq::coordinator::TcpFront::bind(addr, server.clone(), clients + 2)?;
            println!("TCP front-end listening on {}", f.addr());
            Some(f)
        }
        None => None,
    };
    println!(
        "serving {} x {} requests through {} executors{}...",
        clients,
        per,
        cfg.executors,
        if front.is_some() { " over TCP" } else { "" }
    );
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let s = server.clone();
        let suite_tasks = zoo.suite.tasks.len();
        let preset = zoo.preset;
        let tcp = front.as_ref().map(|f| f.addr());
        handles.push(std::thread::spawn(move || -> Result<()> {
            use std::io::{BufRead, BufReader, Write};
            let mut rng = tvq::util::rng::Rng::new(0x5E4E + c as u64);
            let mut conn = match tcp {
                Some(addr) => {
                    let stream = std::net::TcpStream::connect(addr)?;
                    let reader = BufReader::new(stream.try_clone()?);
                    Some((stream, reader))
                }
                None => None,
            };
            for _ in 0..per {
                let task = rng.below(suite_tasks);
                let x = Tensor::randn(&[preset.tokens, preset.token_dim], 1.0, &mut rng);
                match conn.as_mut() {
                    Some((stream, reader)) => {
                        let xs: Vec<String> =
                            x.data().iter().map(|v| format!("{v}")).collect();
                        writeln!(stream, r#"{{"task": {task}, "x": [{}]}}"#, xs.join(","))?;
                        let mut reply = String::new();
                        reader.read_line(&mut reply)?;
                        anyhow::ensure!(reply.contains("logits"), "bad reply: {reply}");
                    }
                    None => {
                        let _ = s.infer(task, &x)?;
                    }
                }
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("client panicked"))??;
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = server.metrics();
    println!("{}", m.summary());
    println!(
        "throughput: {:.0} req/s over {:.2}s",
        m.completed as f64 / dt,
        dt
    );
    Ok(())
}

fn cmd_experiment(argv: &[String]) -> Result<()> {
    let cmd = Command::new("tvq experiment", "regenerate a paper table/figure");
    let args = cmd.parse(argv)?;
    let Some(id) = args.positional.first() else {
        bail!("usage: tvq experiment <id>; ids: {}", exp::EXPERIMENT_IDS.join(", "));
    };
    exp::run_experiment(id)?;
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("presets: vit_s, vit_m, vit_l (+ dense conv trunk)");
    println!("experiments: {}", exp::EXPERIMENT_IDS.join(", "));
    match Runtime::new().and_then(|rt| rt.available()) {
        Ok(mut names) => {
            names.sort();
            println!("artifacts ({}):", names.len());
            for n in names {
                println!("  {n}");
            }
        }
        Err(e) => println!("artifacts: unavailable ({e}) — run `make artifacts`"),
    }
    Ok(())
}
