//! `tvq` — the command-line entrypoint for the TVQ merging system.
//!
//! Subcommands:
//!
//! * `train`      — build (or refresh) a checkpoint zoo via PJRT training.
//! * `quantize`   — quantize a zoo under a scheme; report storage + error.
//! * `merge`      — merge under (method, scheme) and evaluate per task.
//! * `eval`       — evaluate reconstructed single-task models (Individual).
//! * `serve`      — boot the coordinator and run a load demo.
//! * `registry`   — pack / inspect / verify `.qtvc` registries (with
//!   `--budget` the pack planner allocates mixed precision).
//! * `experiment` — regenerate one of the paper's tables/figures by id.
//! * `list`       — show available artifacts, presets, experiments.

use anyhow::{anyhow, bail, Result};

use tvq::checkpoint::Checkpoint;
use tvq::coordinator::{Server, ServerConfig, ServeModel};
use tvq::data::preset_by_name;
use tvq::exp;
use tvq::merge::{standard_methods, Merger};
use tvq::planner::{build_planned_registry, PlannerConfig};
use tvq::quant::QuantScheme;
use tvq::registry::{build_registry, uniform_registry_bytes, DiskAccounting, Registry};
use tvq::runtime::Runtime;
use tvq::tensor::Tensor;
use tvq::train::{TrainConfig, Zoo};
use tvq::util::cli::Command;
use tvq::util::exec::ExecCtx;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    // Global `--trace <out.json>`: record spans for the whole run and
    // export Chrome trace-event JSON at exit.  `TVQ_TRACE=<path>` is
    // the environment equivalent (picked up when the flag is absent).
    let trace_out = match argv.iter().position(|a| a == "--trace") {
        Some(i) if i + 1 < argv.len() => {
            let path = argv.remove(i + 1);
            argv.remove(i);
            tvq::obs::trace::enable();
            Some(path)
        }
        Some(_) => {
            eprintln!("error: --trace needs an output path (e.g. --trace trace.json)");
            std::process::exit(2);
        }
        None => tvq::obs::trace::init_from_env(),
    };
    let result = dispatch(&argv);
    if let Some(path) = &trace_out {
        match tvq::obs::trace::export_to_file(path) {
            Ok(()) => eprintln!(
                "trace: wrote {} spans to {path} (open in chrome://tracing or Perfetto)",
                tvq::obs::trace::events().len()
            ),
            Err(e) => eprintln!("warning: trace export to {path} failed: {e:#}"),
        }
    }
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "tvq — Task Vector Quantization for memory-efficient model merging

usage: tvq <subcommand> [options]

subcommands:
  train       build/refresh a checkpoint zoo (PJRT fine-tuning)
  quantize    quantize task vectors; report storage and error
  merge       merge under a (method, scheme) and evaluate
  eval        evaluate Individual (single-task) models under a scheme
  serve       boot the serving coordinator and run a load demo
              (subactions: `serve status`, `serve watch`,
               `serve metrics`, `serve variants`)
  registry    pack / inspect / verify packed .qtvc registries
  experiment  regenerate a paper table/figure by id (tab1, fig4, ...)
  bench       gate bench JSON reports (ci.sh bench-diff stage)
  list        list presets, artifacts and experiment ids

global options:
  --trace <out.json>  record spans and export a Chrome trace-event file
                      at exit (env: TVQ_TRACE=<out.json>)

environment:
  TVQ_SIMD=off|sse4|avx2|neon  pin the decode/merge SIMD kernel
                               (default: best detected; every kernel is
                               bit-identical to the scalar reference)
  TVQ_THREADS=<n>              default worker-pool width

run `tvq <subcommand> --help` for options."
        .to_string()
}

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(sub) = argv.first() else {
        println!("{}", usage());
        return Ok(());
    };
    let rest = &argv[1..];
    match sub.as_str() {
        "train" => cmd_train(rest),
        "quantize" => cmd_quantize(rest),
        "merge" => cmd_merge(rest),
        "eval" => cmd_eval(rest),
        "serve" => cmd_serve(rest),
        "registry" => cmd_registry(rest),
        "experiment" => cmd_experiment(rest),
        "bench" => cmd_bench(rest),
        "list" => cmd_list(),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n\n{}", usage()),
    }
}

fn zoo_args(cmd: Command) -> Command {
    cmd.opt("preset", "vit_s", "model preset (vit_s | vit_m | vit_l)")
        .opt("tasks", "8", "number of tasks in the suite")
        .opt("steps", "200", "fine-tuning steps per task")
        .opt(
            "threads",
            "0",
            "decode/merge/pack worker threads (0 = auto: TVQ_THREADS, else all cores; 1 = sequential)",
        )
}

/// Apply `--threads` to the process-wide worker pool.  Must run before
/// the first hot-path call; 0 keeps the default (TVQ_THREADS env var,
/// else available parallelism).
fn init_threads(args: &tvq::util::cli::Args) -> Result<()> {
    let n = args.get_usize("threads")?;
    if n > 0 && !tvq::util::pool::Pool::init_global(n) {
        eprintln!("warning: --threads {n} ignored (worker pool already initialized)");
    }
    Ok(())
}

fn load_zoo(args: &tvq::util::cli::Args, rt: &Runtime) -> Result<Zoo> {
    let preset = preset_by_name(args.get_str("preset")?)
        .ok_or_else(|| anyhow!("unknown preset"))?;
    let cfg = TrainConfig { steps: args.get_usize("steps")?, ..TrainConfig::default() };
    Zoo::build_or_load(rt, preset, args.get_usize("tasks")?, &cfg)
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let cmd = zoo_args(Command::new("tvq train", "build/refresh a checkpoint zoo"));
    let args = cmd.parse(argv)?;
    init_threads(&args)?;
    let rt = Runtime::new()?;
    let zoo = load_zoo(&args, &rt)?;
    println!(
        "zoo ready: preset {} | {} tasks | {} params/ckpt | {:.1} MiB fp32 total",
        zoo.preset.name,
        zoo.n_tasks(),
        zoo.pre.numel(),
        (zoo.n_tasks() * zoo.pre.fp32_bytes()) as f64 / (1024.0 * 1024.0),
    );
    Ok(())
}

fn cmd_quantize(argv: &[String]) -> Result<()> {
    let cmd = zoo_args(Command::new("tvq quantize", "quantize a zoo's task vectors"))
        .opt("scheme", "tvq3", "fp32 | fq<b> | tvq<b> | rtvq<bb>o<bo>");
    let args = cmd.parse(argv)?;
    init_threads(&args)?;
    let scheme = QuantScheme::parse(args.get_str("scheme")?)?;
    let rt = Runtime::new()?;
    let zoo = load_zoo(&args, &rt)?;
    let st = exp::scheme_taus(&zoo.pre, &zoo.fts, scheme)?;
    let taus = zoo.task_vectors()?;
    let err: f64 = taus
        .iter()
        .zip(&st.taus)
        .map(|(a, b)| a.l2_dist(b).unwrap_or(f64::NAN))
        .sum();
    let fp32 = zoo.n_tasks() * zoo.pre.fp32_bytes();
    println!(
        "{}: storage {} bytes ({:.1}% of fp32 {fp32}), total L2 error {err:.4e}, {:.3} effective bits/task",
        scheme.label(),
        st.storage_bytes,
        100.0 * st.storage_bytes as f64 / fp32 as f64,
        scheme.effective_bits(zoo.n_tasks()),
    );
    Ok(())
}

fn pick_method(name: &str) -> Result<Box<dyn Merger>> {
    standard_methods()
        .into_iter()
        .find(|m| m.name().eq_ignore_ascii_case(name))
        .ok_or_else(|| {
            anyhow!(
                "unknown method {name:?}; available: {}",
                standard_methods()
                    .iter()
                    .map(|m| m.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })
}

fn cmd_merge(argv: &[String]) -> Result<()> {
    let cmd = zoo_args(Command::new("tvq merge", "merge and evaluate"))
        .opt("scheme", "tvq3", "quantization scheme")
        .opt("method", "task_arithmetic", "merging method");
    let args = cmd.parse(argv)?;
    init_threads(&args)?;
    let scheme = QuantScheme::parse(args.get_str("scheme")?)?;
    let method = pick_method(args.get_str("method")?)?;
    let rt = Runtime::new()?;
    let zoo = load_zoo(&args, &rt)?;
    let st = exp::scheme_taus(&zoo.pre, &zoo.fts, scheme)?;
    let merged = method.merge(&zoo.pre, &st.taus)?;
    let accs = exp::classify::eval_merged(&rt, &zoo, &merged)?;
    for (t, a) in accs.iter().enumerate() {
        println!("task{t:02}: {a:.1}%");
    }
    println!(
        "{} + {}: avg accuracy {:.1}%",
        method.name(),
        scheme.label(),
        accs.iter().sum::<f64>() / accs.len() as f64
    );
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let cmd = zoo_args(Command::new("tvq eval", "evaluate Individual models"))
        .opt("scheme", "fp32", "quantization scheme");
    let args = cmd.parse(argv)?;
    init_threads(&args)?;
    let scheme = QuantScheme::parse(args.get_str("scheme")?)?;
    let rt = Runtime::new()?;
    let zoo = load_zoo(&args, &rt)?;
    let acc = exp::classify::individual_accuracy(&rt, &zoo, scheme)?;
    println!("Individual @ {}: avg accuracy {:.1}%", scheme.label(), acc);
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    // Control-plane subactions ride under `serve`; anything else is the
    // classic load demo.
    match argv.first().map(String::as_str) {
        Some("status") => return cmd_serve_status(&argv[1..]),
        Some("watch") => return cmd_serve_watch(&argv[1..]),
        Some("metrics") => return cmd_serve_metrics(&argv[1..]),
        Some("variants") => return cmd_serve_variants(&argv[1..]),
        _ => {}
    }
    let cmd = zoo_args(Command::new("tvq serve", "serving-coordinator load demo"))
        .long_about(
            "Subactions:
  tvq serve status   --addr <host:port>   query a running front-end's
                                          {\"cmd\": \"status\"} control API
  tvq serve watch    --addr <host:port>   stream live metrics delta
                                          frames (NDJSON) until ^C
  tvq serve metrics  --addr <host:port>   one Prometheus text scrape
  tvq serve variants <registry.qtvc> ...  offline control-plane demo:
                                          load/serve/drain a variant

Without a subaction, boots the in-process serving demo described below.",
        )
        .opt("scheme", "tvq3", "quantization scheme")
        .opt("method", "task_arithmetic", "merging method")
        .opt("requests", "256", "total requests to issue")
        .opt("clients", "4", "concurrent client threads")
        .opt("executors", "2", "PJRT executor threads")
        .opt("max-batch", "32", "max dynamic batch size")
        .opt("max-delay-ms", "2", "batching deadline (ms)")
        .opt("tcp", "", "serve over TCP at this address (e.g. 127.0.0.1:7070) and drive the demo load through it");
    let args = cmd.parse(argv)?;
    init_threads(&args)?;
    let scheme = QuantScheme::parse(args.get_str("scheme")?)?;
    let method = pick_method(args.get_str("method")?)?;
    let rt = Runtime::new()?;
    let zoo = load_zoo(&args, &rt)?;
    let st = exp::scheme_taus(&zoo.pre, &zoo.fts, scheme)?;
    let merged = std::sync::Arc::new(method.merge(&zoo.pre, &st.taus)?);
    let heads = std::sync::Arc::new(
        zoo.suite.tasks.iter().map(|t| t.head.clone()).collect::<Vec<_>>(),
    );
    let model = ServeModel { preset: zoo.preset, merged, heads };
    let cfg = ServerConfig {
        max_batch: args.get_usize("max-batch")?,
        max_delay: std::time::Duration::from_millis(args.get_u64("max-delay-ms")?),
        queue_cap: 4096,
        executors: args.get_usize("executors")?,
        ..Default::default()
    };
    let server = std::sync::Arc::new(Server::start(cfg, model)?);
    let n_req = args.get_usize("requests")?;
    let clients = args.get_usize("clients")?.max(1);
    let per = n_req / clients;
    // Optional TCP front-end: clients go over the wire instead of the
    // in-process API (same batching/metrics path underneath).
    let tcp_addr = args.get("tcp").filter(|a| !a.is_empty()).map(String::from);
    let front = match &tcp_addr {
        Some(addr) => {
            let f = tvq::coordinator::TcpFront::bind(addr, server.clone(), clients + 2)?;
            println!("TCP front-end listening on {}", f.addr());
            Some(f)
        }
        None => None,
    };
    println!(
        "serving {} x {} requests through {} executors{} (simd kernel: {})...",
        clients,
        per,
        cfg.executors,
        if front.is_some() { " over TCP" } else { "" },
        tvq::quant::simd::active().label()
    );
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let s = server.clone();
        let suite_tasks = zoo.suite.tasks.len();
        let preset = zoo.preset;
        let tcp = front.as_ref().map(|f| f.addr());
        handles.push(std::thread::spawn(move || -> Result<()> {
            use std::io::{BufRead, BufReader, Write};
            let mut rng = tvq::util::rng::Rng::new(0x5E4E + c as u64);
            let mut conn = match tcp {
                Some(addr) => {
                    let stream = std::net::TcpStream::connect(addr)?;
                    let reader = BufReader::new(stream.try_clone()?);
                    Some((stream, reader))
                }
                None => None,
            };
            for _ in 0..per {
                let task = rng.below(suite_tasks);
                let x = Tensor::randn(&[preset.tokens, preset.token_dim], 1.0, &mut rng);
                match conn.as_mut() {
                    Some((stream, reader)) => {
                        let xs: Vec<String> =
                            x.data().iter().map(|v| format!("{v}")).collect();
                        writeln!(stream, r#"{{"task": {task}, "x": [{}]}}"#, xs.join(","))?;
                        let mut reply = String::new();
                        reader.read_line(&mut reply)?;
                        anyhow::ensure!(reply.contains("logits"), "bad reply: {reply}");
                    }
                    None => {
                        let _ = s.infer(task, &x)?;
                    }
                }
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("client panicked"))??;
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = server.metrics();
    println!("{}", m.summary());
    println!(
        "throughput: {:.0} req/s over {:.2}s",
        m.completed as f64 / dt,
        dt
    );
    Ok(())
}

fn cmd_serve_status(argv: &[String]) -> Result<()> {
    let cmd = Command::new("tvq serve status", "query a running front-end's control API")
        .long_about(
            "Connects to a TCP front-end (e.g. one started with
`tvq serve --tcp 127.0.0.1:7070`), sends {\"cmd\": \"status\"} and prints
the JSON reply: server metrics, plus per-variant control-plane state
when the front-end was bound with one.",
        )
        .req("addr", "front-end address (host:port)");
    let args = cmd.parse(argv)?;
    use std::io::{BufRead, BufReader, Write};
    let addr = args.get_str("addr")?;
    let mut stream = std::net::TcpStream::connect(addr)
        .map_err(|e| anyhow!("connecting to {addr}: {e}"))?;
    writeln!(stream, r#"{{"cmd": "status"}}"#)?;
    let mut reply = String::new();
    BufReader::new(stream).read_line(&mut reply)?;
    let parsed = tvq::util::json::Json::parse(reply.trim())
        .map_err(|e| anyhow!("malformed status reply {reply:?}: {e}"))?;
    if let Some(err) = parsed.get("error") {
        bail!("front-end returned an error: {}", err.as_str().unwrap_or("?"));
    }
    println!("{}", parsed.to_string_compact());
    Ok(())
}

fn cmd_serve_watch(argv: &[String]) -> Result<()> {
    let cmd = Command::new("tvq serve watch", "stream live metrics frames from a front-end")
        .long_about(
            "Connects to a TCP front-end, sends
{\"cmd\": \"watch\", \"interval_ms\": N} and prints the pushed
newline-delimited JSON delta frames (counters as deltas since the
previous frame, quantiles/gauges as-is) until interrupted, the server
stops, or --frames is reached.",
        )
        .req("addr", "front-end address (host:port)")
        .opt("interval-ms", "1000", "frame interval (ms)")
        .opt("frames", "0", "stop after this many frames (0 = run until interrupted)");
    let args = cmd.parse(argv)?;
    use std::io::{BufRead, BufReader, Write};
    let addr = args.get_str("addr")?;
    let interval = args.get_u64("interval-ms")?;
    let max_frames = args.get_usize("frames")?;
    let mut stream = std::net::TcpStream::connect(addr)
        .map_err(|e| anyhow!("connecting to {addr}: {e}"))?;
    writeln!(stream, r#"{{"cmd": "watch", "interval_ms": {interval}}}"#)?;
    let mut reader = BufReader::new(stream);
    let mut frames = 0usize;
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // front-end shut down
        }
        println!("{}", line.trim_end());
        frames += 1;
        if max_frames > 0 && frames >= max_frames {
            return Ok(());
        }
    }
}

fn cmd_serve_metrics(argv: &[String]) -> Result<()> {
    let cmd = Command::new("tvq serve metrics", "scrape a front-end's Prometheus metrics")
        .long_about(
            "Connects to a TCP front-end, sends {\"cmd\": \"metrics\"} and prints
the Prometheus text exposition (server counters, latency/queue-wait/
merge-build summaries, pool busy, and per-variant families when a
control plane is attached).",
        )
        .req("addr", "front-end address (host:port)");
    let args = cmd.parse(argv)?;
    use std::io::{BufRead, BufReader, Write};
    let addr = args.get_str("addr")?;
    let mut stream = std::net::TcpStream::connect(addr)
        .map_err(|e| anyhow!("connecting to {addr}: {e}"))?;
    writeln!(stream, r#"{{"cmd": "metrics"}}"#)?;
    let mut reader = BufReader::new(stream);
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        if line.trim().is_empty() {
            return Ok(()); // blank-line terminator
        }
        print!("{line}");
    }
}

fn cmd_serve_variants(argv: &[String]) -> Result<()> {
    use tvq::coordinator::control::{ControlPlane, VariantConfig, VariantState};
    use tvq::coordinator::ModelCache;

    let cmd = Command::new(
        "tvq serve variants",
        "offline control-plane demo: load, serve, hot-swap-ready drain",
    )
    .long_about(
        "Loads a packed .qtvc registry as a lifecycle-managed variant, runs a
burst of task-vector reconstructions through its bounded admission
queue, prints per-variant status (as `tvq serve status` would report
it), then drains gracefully and awaits Terminated.

example:
  tvq registry pack --synthetic --out zoo.qtvc --scheme tvq4
  tvq serve variants zoo.qtvc --requests 64",
    )
    .positional_help("<registry.qtvc>  packed registry to serve")
    .opt("requests", "32", "task-vector reconstructions to submit")
    .opt("budget-mb", "0", "node byte budget in MiB (0 = unbounded)")
    .opt("queue-cap", "256", "bounded admission-queue depth")
    .opt("drain-deadline-ms", "500", "graceful-drain deadline (ms)")
    .opt(
        "threads",
        "0",
        "decode worker threads (0 = auto: TVQ_THREADS, else all cores; 1 = sequential)",
    );
    let args = cmd.parse(argv)?;
    init_threads(&args)?;
    let path = args
        .positional
        .first()
        .cloned()
        .ok_or_else(|| anyhow!("usage: tvq serve variants <registry.qtvc> [options]"))?;

    let budget_mb = args.get_usize("budget-mb")?;
    let cache = std::sync::Arc::new(if budget_mb > 0 {
        ModelCache::with_byte_cap(budget_mb << 20)
    } else {
        ModelCache::new()
    });
    let plane = ControlPlane::new(cache);
    let cfg = VariantConfig {
        queue_cap: args.get_usize("queue-cap")?.max(1),
        drain_deadline: std::time::Duration::from_millis(args.get_u64("drain-deadline-ms")?),
        est_model_bytes: 0,
    };
    let variant = plane
        .load_variant("zoo", std::path::Path::new(&path), &cfg)
        .map_err(|e| anyhow!("{e}"))?;
    let n_tasks = variant.registry().pin().registry().n_tasks().max(1);
    println!(
        "variant \"zoo\" ready: {} tasks, generation {}",
        n_tasks,
        variant.registry().generation()
    );

    let n_req = args.get_usize("requests")?;
    let mut pending = Vec::new();
    let mut rejected = 0usize;
    for i in 0..n_req {
        match variant.submit_task_vector(i % n_tasks) {
            Ok(rx) => pending.push(rx),
            Err(e) => {
                rejected += 1;
                eprintln!("request {i} rejected: {e}");
            }
        }
    }
    let mut ok = 0usize;
    for rx in pending {
        match rx.recv() {
            Ok(Ok(_)) => ok += 1,
            Ok(Err(e)) => eprintln!("job failed: {e}"),
            Err(_) => eprintln!("worker dropped a response"),
        }
    }
    println!("completed {ok}/{n_req} reconstructions ({rejected} rejected at admission)");
    print!("{}", plane.status().summary());

    plane.drain_variant("zoo", None).map_err(|e| anyhow!("{e}"))?;
    let deadline = std::time::Duration::from_millis(args.get_u64("drain-deadline-ms")?)
        + std::time::Duration::from_secs(5);
    if !variant.await_state(&VariantState::Terminated, deadline) {
        bail!("variant did not reach Terminated within {deadline:?}");
    }
    println!("drained; final status:");
    print!("{}", plane.status().summary());
    Ok(())
}

fn registry_usage() -> String {
    "tvq registry — pack / inspect / verify / route packed .qtvc registries

usage:
  tvq registry pack --out <file> [--scheme tvq4 | --budget <bytes|scheme>]
                    [--group 512] [--synthetic] [--preset .. --tasks .. --steps ..]
  tvq registry inspect <file>
  tvq registry verify <file>
  tvq registry route <file> --tasks 0,2,5 [--lambdas 0.3,0.3,-0.1] [--chain]
  tvq registry shard <file> --out <dir> [--shards 4] [--page-rows 64]
  tvq registry fetch-serve <dir/MANIFEST.qtvm> [--addr 127.0.0.1:7843]
                           [--workers 4]

`verify` refuses mid-swap artifacts (`*.tmp`, `*.next`) with a non-zero
exit: validate the serving path, not a file a rename is about to consume.

`shard` splits a plan-packed registry into content-addressed shard files
plus a `MANIFEST.qtvm` (identical sections dedup across shards);
`fetch-serve` exposes a sharded zoo's chunks to remote tier-1 readers
over the `fetch_section` TCP protocol.

`route` maps a dynamic merge request (task subset + per-task lambdas)
to its canonical variant key and serves it through the incremental-merge
cache; `--chain` issues every prefix first, so each later request is
served as a one-task delta patch instead of a full re-merge.

`pack --budget` invokes the sensitivity-driven pack planner: the budget
is total file bytes, either a number (`1500000`) or a uniform scheme
spelling (`rtvq3o2` = \"whatever that scheme would cost on disk\").  The
planner's candidate set includes sparse DARE / TALL-mask arms (kind-4
sections, QTVC v4) and 1-bit binary-switch arms (kind-5 sections,
QTVC v5).  `--synthetic` packs the built-in heterogeneous demo zoo
instead of a PJRT-trained one (useful offline).

Run `tvq registry <action> --help` for per-action details; copy-pasteable
walkthroughs live in docs/CLI.md, the byte-level file format in
docs/WIRE_FORMAT.md."
        .to_string()
}

fn cmd_registry(argv: &[String]) -> Result<()> {
    let Some(action) = argv.first() else {
        println!("{}", registry_usage());
        return Ok(());
    };
    let rest = &argv[1..];
    match action.as_str() {
        "pack" => cmd_registry_pack(rest),
        "inspect" => cmd_registry_inspect(rest),
        "verify" => cmd_registry_verify(rest),
        "route" => cmd_registry_route(rest),
        "shard" => cmd_registry_shard(rest),
        "fetch-serve" => cmd_registry_fetch_serve(rest),
        "--help" | "-h" | "help" => {
            println!("{}", registry_usage());
            Ok(())
        }
        other => bail!("unknown registry action {other:?}\n\n{}", registry_usage()),
    }
}

/// Resolve `--budget`: raw bytes, or a uniform scheme whose exact
/// on-disk cost becomes the budget.
fn parse_budget(spec: &str, pre: &Checkpoint, fts: &[Checkpoint]) -> Result<u64> {
    if let Ok(bytes) = spec.parse::<u64>() {
        return Ok(bytes);
    }
    let scheme = QuantScheme::parse(spec).map_err(|e| {
        anyhow!("--budget {spec:?} is neither a byte count nor a scheme: {e}")
    })?;
    let bytes = uniform_registry_bytes(pre, fts, scheme)?;
    println!("budget: {} B (= uniform {} on this zoo)", bytes, scheme.label());
    Ok(bytes)
}

fn cmd_registry_pack(argv: &[String]) -> Result<()> {
    let cmd = zoo_args(
        Command::new("tvq registry pack", "pack a zoo into a .qtvc registry")
            .long_about(
                "Without --budget, packs every task at one uniform scheme (QTVC v2).
With --budget, runs the sensitivity probe + solver over the full candidate
set — per-task TVQ widths, shared-base RTVQ splits, the sparse
DARE / TALL-mask arms, and the 1-bit binary-switch arms — and compiles
the winning plan into a mixed-precision registry (QTVC v3; v4 when
sparse arms are chosen, v5 when 1-bit arms are).
The budget is total file bytes, index included, and is respected exactly.

examples:
  tvq registry pack --synthetic --out zoo.qtvc --scheme rtvq3o2
  tvq registry pack --synthetic --budget rtvq3o2 --out planned.qtvc
  tvq registry pack --synthetic --tasks 8 --budget 900000 --out small.qtvc",
            ),
    )
    .req("out", "output .qtvc path")
    .opt("scheme", "tvq4", "uniform scheme when no --budget is given")
    .opt("budget", "", "planner byte budget: a number or a scheme spelling")
    .opt("group", "512", "planner group-quantization width")
    .switch("synthetic", "use the built-in heterogeneous demo zoo (no PJRT)");
    let args = cmd.parse(argv)?;
    init_threads(&args)?;
    let out = args.get_str("out")?.to_string();
    let n_tasks = args.get_usize("tasks")?;

    let (pre, fts) = if args.switch("synthetic") {
        exp::planner::synthetic_planner_zoo(n_tasks, 0x7AB9)
    } else {
        let rt = Runtime::new()?;
        let zoo = load_zoo(&args, &rt)?;
        (zoo.pre.clone(), zoo.fts.clone())
    };

    let budget_spec = args.get_str("budget")?.to_string();
    if budget_spec.is_empty() {
        let scheme = QuantScheme::parse(args.get_str("scheme")?)?;
        let summary = build_registry(&pre, &fts, scheme, &out)?;
        println!(
            "packed {} tasks at {} -> {} ({} B: {} index + {} payload)",
            summary.n_tasks,
            scheme.label(),
            out,
            summary.file_bytes,
            summary.index_bytes,
            summary.payload_bytes
        );
        return Ok(());
    }

    let budget = parse_budget(&budget_spec, &pre, &fts)?;
    let cfg = PlannerConfig { group: args.get_usize("group")?, ..PlannerConfig::default() };
    let (plan, summary) = build_planned_registry(&pre, &fts, budget, &cfg, &out)?;
    println!(
        "planned {} tasks x {} tensors -> {} ({} B of {} B budget, total SSE {:.4e})",
        plan.n_tasks(),
        plan.n_tensors(),
        out,
        summary.file_bytes,
        budget,
        plan.total_error()
    );
    for (tensor, a) in plan.tensors.iter().zip(&plan.assignments) {
        println!(
            "  {:<20} {:<10} {:>9} B  SSE {:.4e}",
            tensor.name,
            a.arm.label(),
            a.cost_bytes,
            a.error
        );
    }
    Ok(())
}

fn registry_path_arg(cmd: Command, argv: &[String], action: &str) -> Result<String> {
    let args = cmd.parse(argv)?;
    args.positional
        .first()
        .cloned()
        .ok_or_else(|| anyhow!("usage: tvq registry {action} <file.qtvc>"))
}

fn cmd_registry_inspect(argv: &[String]) -> Result<()> {
    let cmd = Command::new("tvq registry inspect", "dump a .qtvc registry's layout")
        .long_about(
            "Opens the registry (header + CRC'd offset table only; payloads stay on
disk) and prints one row per section: name, kind (0 task checkpoint,
1 RTVQ base, 2 group, 3 plan, 4 sparse, 5 binary switch), offset,
length, CRC, and the
arm family serving that section (e.g. TVQ-INT4, RTVQ-B3O2 base,
TALL-K25B4).  For planned registries the embedded pack plan and its
per-tensor allocation follow, then the disk accounting vs the
metadata-free ideal.

example:
  tvq registry pack --synthetic --budget rtvq3o2 --out zoo.qtvc
  tvq registry inspect zoo.qtvc",
        )
        .positional_help("<registry.qtvc>  packed registry to inspect");
    let path = registry_path_arg(cmd, argv, "inspect")?;
    let reg = Registry::open(&path)?;
    println!(
        "{}: QTVC v{} {} | {} tasks | {} B ({} index + {} payload)",
        path,
        reg.version(),
        reg.scheme().label(),
        reg.n_tasks(),
        reg.file_bytes(),
        reg.index_bytes(),
        reg.payload_bytes()
    );
    // Arm family per section: from the plan for planned registries, from
    // the scheme + kind for uniform ones.
    let mut family: std::collections::HashMap<String, String> = std::collections::HashMap::new();
    if let Some(plan) = reg.plan() {
        family.insert(tvq::planner::plan::PLAN_SECTION_NAME.to_string(), "plan".to_string());
        for (name, role) in plan.expected_sections() {
            let (tensor, is_base) = match role {
                tvq::planner::SectionRole::Base { tensor } => (tensor, true),
                tvq::planner::SectionRole::Task { tensor, .. } => (tensor, false),
            };
            let label = plan.assignments[tensor].arm.label();
            family.insert(name, if is_base { format!("{label} base") } else { label });
        }
    }
    println!(
        "{:<28} {:>5} {:>10} {:>10} {:>10}  {}",
        "section", "kind", "offset", "bytes", "crc32", "arm"
    );
    for e in reg.entries() {
        let fam = family.get(&e.name).cloned().unwrap_or_else(|| match e.kind.to_u8() {
            0 => reg.scheme().label(),
            1 => "RTVQ base".to_string(),
            _ => "-".to_string(),
        });
        println!(
            "{:<28} {:>5} {:>10} {:>10}   {:08x}  {}",
            e.name,
            e.kind.to_u8(),
            e.offset,
            e.length,
            e.crc,
            fam
        );
    }
    if let Some(plan) = reg.plan() {
        println!(
            "plan: budget {} B, planned {} B, total SSE {:.4e}",
            plan.budget_bytes,
            plan.planned_file_bytes(),
            plan.total_error()
        );
        for (tensor, a) in plan.tensors.iter().zip(&plan.assignments) {
            println!(
                "  {:<20} {:<10} group {:<5} {:>9} B  SSE {:.4e}",
                tensor.name,
                a.arm.label(),
                tensor.group,
                a.cost_bytes,
                a.error
            );
        }
    }
    let acc = DiskAccounting::measure(&reg)?;
    println!(
        "accounting: ideal {} B, overhead +{:.2}%, {:.1}% of fp32",
        acc.ideal_bytes,
        100.0 * acc.overhead_fraction(),
        100.0 * acc.fraction_of_fp32()
    );
    Ok(())
}

fn cmd_registry_route(argv: &[String]) -> Result<()> {
    let cmd = Command::new(
        "tvq registry route",
        "route a dynamic merge request through the incremental-merge cache",
    )
    .long_about(
        "Canonicalizes the request (sorted unique task indices, bit-exact
lambdas) into its variant key and serves it from the registry through
the routed merge engine.  The composition is served over a zero trunk —
the result is the composed task vector sum lambda_i * tau_i, which is
what the registry alone can provide (the pre-trained trunk ships
separately in a deployment).

With --chain, every prefix of the (sorted) request is issued first:
request k+1 then differs from cached request k by one appended task, so
the engine serves it as a single signed axpy over the cached floats (a
delta patch) instead of a full re-merge — the per-request log shows
which path each one took, and the summary line the patch/build counts.

examples:
  tvq registry pack --synthetic --budget rtvq3o2 --out zoo.qtvc
  tvq registry route zoo.qtvc --tasks 0,2,5
  tvq registry route zoo.qtvc --tasks 0,1,2,3 --lambdas 0.3,0.3,0.2,-0.1 --chain",
    )
    .opt("tasks", "", "comma-separated task indices to compose (required)")
    .opt("lambdas", "", "comma-separated per-task coefficients (default 0.3 each)")
    .switch("chain", "issue every prefix first: a delta-patch walk up the request")
    .positional_help("<registry.qtvc>  packed registry to serve from");
    let args = cmd.parse(argv)?;
    let path = args
        .positional
        .first()
        .cloned()
        .ok_or_else(|| anyhow!("usage: tvq registry route <file.qtvc> --tasks 0,2,5"))?;
    let tasks_spec = args.get_str("tasks")?.to_string();
    if tasks_spec.is_empty() {
        bail!("--tasks is required (e.g. --tasks 0,2,5)");
    }
    let tasks: Vec<usize> = tasks_spec
        .split(',')
        .map(|s| s.trim().parse::<usize>().map_err(|e| anyhow!("bad task index {s:?}: {e}")))
        .collect::<Result<_>>()?;
    let lambdas_spec = args.get_str("lambdas")?.to_string();
    let lambdas: Vec<f32> = if lambdas_spec.is_empty() {
        vec![0.3; tasks.len()]
    } else {
        lambdas_spec
            .split(',')
            .map(|s| s.trim().parse::<f32>().map_err(|e| anyhow!("bad lambda {s:?}: {e}")))
            .collect::<Result<_>>()?
    };

    let source = tvq::registry::PackedRegistrySource::open(&path)?;
    let router = tvq::coordinator::Router::new(source.n_tasks());
    let spec = router.route(&tasks, &lambdas)?;
    // Zero trunk with the registry's tensor geometry: the served model is
    // the composed task vector itself.
    let pre = source.task_vector(spec.pairs()[0].0)?.scale(0.0);
    let cache = tvq::coordinator::ModelCache::new();
    let metrics = std::sync::Arc::new(tvq::coordinator::Metrics::new());
    cache.set_metrics(metrics.clone());

    let mut requests: Vec<tvq::coordinator::MergeSpec> = Vec::new();
    if args.switch("chain") {
        for k in 1..spec.len() {
            let prefix = &spec.pairs()[..k];
            let ts: Vec<usize> = prefix.iter().map(|&(t, _)| t).collect();
            let ls: Vec<f32> = prefix.iter().map(|&(_, l)| l).collect();
            requests.push(router.route(&ts, &ls)?);
        }
    }
    requests.push(spec);
    for spec in &requests {
        let before = metrics.snapshot();
        let t0 = std::time::Instant::now();
        let served = cache.get_or_merge_routed(spec, &pre, &source)?;
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let after = metrics.snapshot();
        let via = if after.delta_patches > before.delta_patches {
            "delta patch"
        } else if after.merge_builds > before.merge_builds {
            "full build"
        } else {
            "cache hit"
        };
        let (_, key) = spec.variant_key(&source.source_id());
        println!(
            "{key}\n  tasks {:?} -> {via} in {wall_ms:.2} ms ({} tensors)",
            spec.tasks(),
            served.for_task(0).len()
        );
    }
    let s = metrics.snapshot();
    println!(
        "served {} request(s): {} full build(s), {} delta patch(es), {} resident B",
        requests.len(),
        s.merge_builds,
        s.delta_patches,
        cache.resident_bytes()
    );
    Ok(())
}

fn cmd_registry_verify(argv: &[String]) -> Result<()> {
    let cmd = Command::new("tvq registry verify", "decode-verify every section of a registry")
        .long_about(
            "Full read-path verification, strictest first: header magic/version/
scheme pairing, offset-table bounds, index CRC, plan decode + section
coverage (planned files), then every task's payload sections — each
read CRC-checked and round-tripped through dequantization.  Any
corruption (flipped byte, truncated bitmask, survivor-count mismatch,
missing section) fails with a pointed error and a non-zero exit.
Mid-swap artifacts (`.tmp` writer staging, `.next` staged generations)
are refused outright — their identity is about to change under a rename.

example:
  tvq registry verify zoo.qtvc && echo servable",
        )
        .positional_help("<registry.qtvc>  packed registry to verify");
    let path = registry_path_arg(cmd, argv, "verify")?;
    if tvq::coordinator::control::is_swap_artifact(std::path::Path::new(&path)) {
        bail!(
            "{path} is a swap artifact, not a servable registry: `.tmp` is the \
             writer's interrupted atomic-write staging file and `.next` is a \
             staged next generation awaiting publish. Verify the serving path \
             instead, or publish the stage first (rename it over the serving \
             path); see docs/WIRE_FORMAT.md §7."
        );
    }
    // Open validates the header, offset table, index CRC and (for
    // planned files) the plan section + section coverage.
    let reg = Registry::open(&path)?;
    // Decode every task end-to-end: reads each section (per-section CRC)
    // and round-trips the quantized payloads through dequantization.
    for t in 0..reg.n_tasks() {
        reg.load_task_vector(t, &ExecCtx::sequential())
            .map_err(|e| anyhow!("task {t} failed decode round-trip: {e:#}"))?;
    }
    println!(
        "{}: OK ({} sections, {} tasks, {} B)",
        path,
        reg.entries().len(),
        reg.n_tasks(),
        reg.file_bytes()
    );
    Ok(())
}

fn cmd_registry_shard(argv: &[String]) -> Result<()> {
    let cmd = Command::new(
        "tvq registry shard",
        "split a plan-packed registry into content-addressed shards + manifest",
    )
    .long_about(
        "Reads a plan-packed (PLAN-MIXED) registry and writes its sections as
content-addressed chunks across N shard files (`shard-xx.qtvs`), plus a
`MANIFEST.qtvm` with a paged index mapping every section to its chunk
(shard, offset, length, CRC-32, FNV-64 content hash).  Byte-identical
sections — shared RTVQ bases, duplicated deltas — are stored once and
referenced from every row that needs them, so a zoo with shared bases
shards to fewer bytes than the monolithic file.

The sharded zoo round-trips bit-exactly: open the manifest with
ShardedRegistry (tier 0) or serve it remotely with `fetch-serve`
(tier 1); fused merges and routed dynamic merges produce floats
identical to the single-file registry.

examples:
  tvq registry pack --synthetic --budget rtvq3o2 --out zoo.qtvc
  tvq registry shard zoo.qtvc --out zoo-shards --shards 4",
    )
    .req("out", "output directory for the manifest + shard files")
    .opt("shards", "4", "number of shard files")
    .opt("page-rows", "64", "manifest index rows per page")
    .positional_help("<registry.qtvc>  plan-packed registry to shard");
    let args = cmd.parse(argv)?;
    let path = args
        .positional
        .first()
        .cloned()
        .ok_or_else(|| anyhow!("usage: tvq registry shard <file.qtvc> --out <dir>"))?;
    let out_dir = std::path::PathBuf::from(args.get_str("out")?);
    let opts = tvq::registry::ShardOptions {
        n_shards: args.get_usize("shards")?,
        page_rows: args.get_usize("page-rows")?,
    };
    let src = Registry::open(&path)?;
    std::fs::create_dir_all(&out_dir)?;
    let summary = tvq::registry::shard_registry(&src, &out_dir, &opts)?;
    println!(
        "sharded {} -> {} ({} shard files)",
        path,
        summary.manifest_path.display(),
        summary.shard_paths.len()
    );
    println!(
        "  {} sections, {} unique chunks, {} dedup hit(s)",
        summary.n_sections, summary.n_unique_chunks, summary.n_dedup_hits
    );
    println!(
        "  {} B total ({} B shards + {} B manifest) vs {} B monolithic ({:+.1}%)",
        summary.total_bytes(),
        summary.shard_bytes,
        summary.manifest_bytes,
        summary.source_bytes,
        100.0 * (summary.total_bytes() as f64 / summary.source_bytes as f64 - 1.0)
    );
    Ok(())
}

fn cmd_registry_fetch_serve(argv: &[String]) -> Result<()> {
    let cmd = Command::new(
        "tvq registry fetch-serve",
        "serve a sharded zoo's chunks to remote tier-1 readers over TCP",
    )
    .long_about(
        "Binds the `fetch_section` protocol over one sharded zoo: each request
names a (shard, offset, length) range from the client's manifest and
gets the raw bytes back (the client verifies CRC-32 + content hash
against its own manifest, so a stale or corrupt shard here fails closed
at the reader exactly as it would locally).  Requests dispatch
round-robin into a bounded-mailbox worker pool; full mailboxes block
the dispatching connection (backpressure), never grow a queue.

examples:
  tvq registry shard zoo.qtvc --out zoo-shards
  tvq registry fetch-serve zoo-shards/MANIFEST.qtvm --addr 127.0.0.1:7843",
    )
    .opt("addr", "127.0.0.1:7843", "address to bind")
    .opt("workers", "4", "fetch worker threads")
    .opt("max-conns", "64", "concurrent connection cap")
    .opt("duration-secs", "0", "serve for N seconds then exit (0 = forever)")
    .positional_help("<dir/MANIFEST.qtvm>  manifest of the sharded zoo to serve");
    let args = cmd.parse(argv)?;
    let manifest = args
        .positional
        .first()
        .cloned()
        .ok_or_else(|| anyhow!("usage: tvq registry fetch-serve <dir/MANIFEST.qtvm>"))?;
    let pool = std::sync::Arc::new(tvq::coordinator::SectionFetchPool::open(
        std::path::Path::new(&manifest),
        args.get_usize("workers")?,
    )?);
    let front = tvq::coordinator::TcpFront::bind_sections(
        args.get_str("addr")?,
        pool.clone(),
        args.get_usize("max-conns")?,
    )?;
    println!("serving sections of {} on {}", manifest, front.addr());
    let duration = args.get_usize("duration-secs")?;
    if duration == 0 {
        loop {
            std::thread::sleep(std::time::Duration::from_secs(60));
            let (served, errors) = pool.stats();
            println!("served {served} chunk(s), {errors} error(s)");
        }
    }
    std::thread::sleep(std::time::Duration::from_secs(duration as u64));
    let (served, errors) = pool.stats();
    println!("done: served {served} chunk(s), {errors} error(s)");
    Ok(())
}

fn bench_usage() -> String {
    "tvq bench — machine-readable benchmark gating

usage:
  tvq bench diff --current <BENCH_x.json> [--baseline <file>] [--tolerance 0.20]

`diff` enforces (1) the ordering invariants a bench declares about its own
run (e.g. mmap section reads must not be slower than pread) and (2), when
the baseline carries `calibrated: true`, per-case mean-time regressions
beyond the tolerance.  Uncalibrated baselines record without gating, so a
fresh machine class can bootstrap: run the bench, inspect, commit the
fresh report with `calibrated: true`."
        .to_string()
}

fn cmd_bench(argv: &[String]) -> Result<()> {
    let Some(action) = argv.first() else {
        println!("{}", bench_usage());
        return Ok(());
    };
    match action.as_str() {
        "diff" => cmd_bench_diff(&argv[1..]),
        "--help" | "-h" | "help" => {
            println!("{}", bench_usage());
            Ok(())
        }
        other => bail!("unknown bench action {other:?}\n\n{}", bench_usage()),
    }
}

fn cmd_bench_diff(argv: &[String]) -> Result<()> {
    let cmd = Command::new("tvq bench diff", "gate a bench JSON report against a baseline")
        .long_about(
            "Reads the current run's BENCH_*.json, checks the within-run ordering
invariants it declares, and — when the baseline file is calibrated —
fails on any case whose mean time regressed past the tolerance.
Exits non-zero on violation, so ci.sh can gate on it.

example:
  TVQ_BENCH_OUT=target/BENCH_registry.json cargo bench --bench perf_registry
  tvq bench diff --current target/BENCH_registry.json \\
                 --baseline rust/benches/baselines/BENCH_registry.json",
        )
        .req("current", "fresh BENCH_*.json from this run")
        .opt("baseline", "", "committed baseline JSON (empty = invariants only)")
        .opt("tolerance", "0.20", "relative tolerance (0.20 = +/-20%)");
    let args = cmd.parse(argv)?;
    let current_path = args.get_str("current")?;
    let current = tvq::util::json::Json::parse(
        &std::fs::read_to_string(current_path)
            .map_err(|e| anyhow!("reading --current {current_path}: {e}"))?,
    )?;
    let baseline_path = args.get_str("baseline")?.to_string();
    let baseline = if baseline_path.is_empty() {
        None
    } else {
        Some(tvq::util::json::Json::parse(
            &std::fs::read_to_string(&baseline_path)
                .map_err(|e| anyhow!("reading --baseline {baseline_path}: {e}"))?,
        )?)
    };
    let tolerance: f64 = args.get_str("tolerance")?.parse()?;
    let report = tvq::util::benchcmp::diff_reports(&current, baseline.as_ref(), tolerance)?;
    for note in &report.notes {
        println!("  {note}");
    }
    if !report.ok() {
        for f in &report.failures {
            eprintln!("  {f}");
        }
        bail!("bench regression gate failed ({} violation(s))", report.failures.len());
    }
    println!(
        "bench diff: OK ({} check(s), tolerance {:.0}%)",
        report.notes.len(),
        100.0 * tolerance
    );
    Ok(())
}

fn cmd_experiment(argv: &[String]) -> Result<()> {
    let cmd = Command::new("tvq experiment", "regenerate a paper table/figure")
        .long_about(
            "Takes one experiment id, regenerates that table/figure, prints it and
persists markdown under target/results/<id>.md.  `tab5` (storage),
`tabP` (pack planner: uniform vs dense-planned vs sparse-planned at
equal byte budgets) and `tabR` (routed dynamic merging vs static
variant serving, bit-exactness audited) run fully offline; every other
id needs the PJRT runtime (`make artifacts`).  Set TVQ_SMOKE=1 to
shrink tabP/tabR for CI.

examples:
  tvq experiment tabP
  TVQ_SMOKE=1 tvq experiment tabR
  tvq experiment tab1",
        );
    let args = cmd.parse(argv)?;
    let Some(id) = args.positional.first() else {
        bail!("usage: tvq experiment <id>; ids: {}", exp::EXPERIMENT_IDS.join(", "));
    };
    exp::run_experiment(id)?;
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("presets: vit_s, vit_m, vit_l (+ dense conv trunk)");
    println!("experiments: {}", exp::EXPERIMENT_IDS.join(", "));
    let kernels: Vec<&str> =
        tvq::quant::simd::detected().iter().map(|k| k.label()).collect();
    println!(
        "simd kernels: {} (active: {}; override with TVQ_SIMD)",
        kernels.join(", "),
        tvq::quant::simd::active().label()
    );
    match Runtime::new().and_then(|rt| rt.available()) {
        Ok(mut names) => {
            names.sort();
            println!("artifacts ({}):", names.len());
            for n in names {
                println!("  {n}");
            }
        }
        Err(e) => println!("artifacts: unavailable ({e}) — run `make artifacts`"),
    }
    Ok(())
}
