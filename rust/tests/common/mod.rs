//! Shared fixtures for the integration suites (`mod common;` in each
//! suite file; `Cargo.toml` sets `autotests = false`, so this directory
//! is never compiled as a test target of its own).

pub mod fixtures;
