//! One home for the fixture code the nine integration suites used to
//! copy-paste: synthetic zoos, temp-dir naming, registry packing, the
//! CRC-restamping corruption helpers, and the PJRT / bit-exactness
//! utilities.  Every suite compiles this module independently and uses
//! its own subset, hence the file-wide `dead_code` allowance.

#![allow(dead_code)]

use std::path::{Path, PathBuf};

use tvq::checkpoint::Checkpoint;
use tvq::planner::{probe, solve, write_planned_registry, PackPlan, PlannerConfig};
use tvq::quant::QuantScheme;
use tvq::registry::{build_registry, shard_registry, IoMode, Registry, ShardOptions, ShardSummary};
use tvq::runtime::Runtime;
use tvq::tensor::Tensor;
use tvq::util::crc32;
use tvq::util::exec::ExecCtx;
use tvq::util::rng::Rng;

/// Thread counts per the PR-5 determinism contract: 1 is the sequential
/// reference (runs inline on the caller, no workers), 2 is the smallest
/// real pool, 8 gives more workers than work items / shards on some
/// tensors so the ragged-split edge cases run too.
pub const THREADS: [usize; 3] = [1, 2, 8];

/// The three section-read modes, for every-mode sweeps.
pub const IO_MODES: [IoMode; 3] = [IoMode::Mmap, IoMode::Pread, IoMode::Reopen];

/// True when the suite runs under the CI smoke gate (`TVQ_SMOKE=1`):
/// shrink the load, never the assertions.
pub fn smoke() -> bool {
    std::env::var_os("TVQ_SMOKE").is_some()
}

/// Deterministic per-test scratch path (not created): distinct suites
/// pass distinct prefixes so concurrent `cargo test` binaries never
/// collide.  Callers `remove_dir_all(..).ok()` at entry and exit.
pub fn tmp(suite: &str, name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("tvq_{suite}_{name}"))
}

/// Created per-process scratch directory (pid-suffixed) for suites that
/// want the directory to exist up front.
pub fn tmpdir(suite: &str, tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("tvq-{suite}-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Synthetic zoo in the common-drift regime RTVQ expects: a shared drift
/// plus small per-task offsets, big enough (24_832 params/ckpt) that
/// registry metadata is a low-single-digit percent of payload bytes.
pub fn drift_zoo(n_tasks: usize, seed: u64) -> (Checkpoint, Vec<Checkpoint>) {
    let mut rng = Rng::new(seed);
    let mut pre = Checkpoint::new();
    pre.insert("blk00/w", Tensor::randn(&[128, 96], 0.3, &mut rng));
    pre.insert("blk01/w", Tensor::randn(&[128, 96], 0.3, &mut rng));
    pre.insert("head/b", Tensor::randn(&[256], 0.1, &mut rng));
    let mut drift = Checkpoint::new();
    for (name, t) in pre.iter() {
        drift.insert(name, Tensor::randn(t.shape(), 0.02, &mut rng));
    }
    let fts = (0..n_tasks)
        .map(|_| {
            let mut off = Checkpoint::new();
            for (name, t) in pre.iter() {
                off.insert(name, Tensor::randn(t.shape(), 0.005, &mut rng));
            }
            pre.add(&drift).unwrap().add(&off).unwrap()
        })
        .collect();
    (pre, fts)
}

/// Heterogeneous zoo for planner / determinism suites: per-layer scales
/// spanning 25x (so the planner mixes dense arm widths) plus a localized
/// ~8%-perturbed layer (so TALL/DARE sparse arms win somewhere and
/// kind-4 sections are served).  Tensors are sized above the fused-merge
/// small-tensor inline threshold (32Ki elements) so the parallel shard
/// path genuinely runs, and not group-divisible so padding paths run too.
pub fn het_zoo(n_tasks: usize, seed: u64) -> (Checkpoint, Vec<Checkpoint>) {
    let mut rng = Rng::new(seed);
    let stds = [0.002f32, 0.02, 0.05];
    let mut pre = Checkpoint::new();
    for (i, _) in stds.iter().enumerate() {
        pre.insert(&format!("blk{i:02}/w"), Tensor::randn(&[256, 160], 0.3, &mut rng));
    }
    pre.insert("loc/w", Tensor::randn(&[256, 128], 0.3, &mut rng));
    let fts = (0..n_tasks)
        .map(|_| {
            let mut ft = pre.clone();
            for (name, t) in ft.iter_mut() {
                if name == "loc/w" {
                    // Localized deltas: each task perturbs ~8% of entries.
                    for v in t.data_mut() {
                        if rng.f32() < 0.08 {
                            *v += rng.normal_f32(0.1);
                        }
                    }
                } else {
                    let std = stds[name[3..5].parse::<usize>().unwrap()];
                    for v in t.data_mut() {
                        *v += rng.normal_f32(std);
                    }
                }
            }
            ft
        })
        .collect();
    (pre, fts)
}

/// Candidate set covering all four arm families at a group width that
/// does not divide the [`het_zoo`] tensor sizes evenly (padding paths
/// included).
pub fn het_cfg() -> PlannerConfig {
    PlannerConfig {
        group: 384,
        tvq_bits: vec![2, 3, 4],
        rtvq_arms: vec![(3, 2)],
        dare_arms: vec![(75, 3)],
        tall_arms: vec![(25, 4)],
        onebit_arms: vec![],
    }
}

/// Candidate set with nothing but the 1-bit OneBit arms, forcing every
/// tensor onto a kind-5 binary-switch section (and the file onto v5).
pub fn onebit_cfg(group: usize) -> PlannerConfig {
    PlannerConfig {
        group,
        tvq_bits: vec![],
        rtvq_arms: vec![],
        dare_arms: vec![],
        tall_arms: vec![],
        onebit_arms: vec![false, true],
    }
}

/// Small random checkpoint (mixed ranks, 74 params) for property tests.
pub fn rand_ck(rng: &mut Rng, std: f32) -> Checkpoint {
    let mut ck = Checkpoint::new();
    let shapes: &[&[usize]] = &[&[7, 5], &[13], &[3, 2, 4]];
    for (i, shape) in shapes.iter().enumerate() {
        ck.insert(&format!("t{i}"), Tensor::randn(shape, std, rng));
    }
    ck
}

/// Pack a TVQ-INT4 registry of a small synthetic zoo at `dir/name` and
/// return `(path, per-task decoded baselines)`.  Baselines are decoded
/// sequentially from a throwaway open, so they are independent of
/// anything the caller's control plane / cache later does.
pub fn pack_tvq4(dir: &Path, name: &str, n_tasks: usize, seed: u64) -> (PathBuf, Vec<Checkpoint>) {
    let (pre, fts) = tvq::exp::planner::synthetic_planner_zoo(n_tasks, seed);
    let path = dir.join(name);
    build_registry(&pre, &fts, QuantScheme::Tvq(4), &path).unwrap();
    let reg = Registry::open(&path).unwrap();
    let ctx = ExecCtx::sequential();
    let baselines = (0..n_tasks).map(|t| reg.load_task_vector(t, &ctx).unwrap()).collect();
    (path, baselines)
}

/// Probe + solve (unbounded budget) + write a plan-packed registry of a
/// synthetic planner zoo under `cfg`; returns the file path, the zoo and
/// the chosen plan.
pub fn pack_planned(
    dir: &Path,
    name: &str,
    n_tasks: usize,
    seed: u64,
    cfg: &PlannerConfig,
) -> (PathBuf, Checkpoint, Vec<Checkpoint>, PackPlan) {
    let (pre, fts) = tvq::exp::planner::synthetic_planner_zoo(n_tasks, seed);
    let profile = probe(&pre, &fts, cfg).unwrap();
    let plan = solve(&profile, u64::MAX).unwrap();
    let path = dir.join(name);
    write_planned_registry(&pre, &fts, &plan, &path).unwrap();
    (path, pre, fts, plan)
}

/// Shard-zoo fixture (ISSUE 9 acceptance): plan-pack a zoo in which
/// task 1 is a byte-for-byte clone of task 0 — identical deltas
/// quantize to identical section bodies, so content-addressed chunk
/// dedup must fire when the file is split into shards — then shard it
/// into `dir`.  Returns the monolithic path, the manifest path, the
/// zoo, and the shard summary.
pub fn shard_zoo(
    dir: &Path,
    n_tasks: usize,
    seed: u64,
    opts: &ShardOptions,
) -> (PathBuf, PathBuf, Checkpoint, Vec<Checkpoint>, ShardSummary) {
    assert!(n_tasks >= 2, "the shard zoo clones task 0 into task 1");
    let (pre, mut fts) = tvq::exp::planner::synthetic_planner_zoo(n_tasks, seed);
    fts[1] = fts[0].clone();
    let profile = probe(&pre, &fts, &PlannerConfig::default()).unwrap();
    let plan = solve(&profile, u64::MAX).unwrap();
    let path = dir.join("zoo.qtvc");
    write_planned_registry(&pre, &fts, &plan, &path).unwrap();
    let src = Registry::open(&path).unwrap();
    let summary = shard_registry(&src, dir, opts).unwrap();
    (path, summary.manifest_path.clone(), pre, fts, summary)
}

/// PJRT skip helper: integration suites skip — not fail — when the
/// runtime can't start (offline builds use the vendored `xla` stub,
/// which has no client).
pub fn runtime() -> Option<Runtime> {
    match Runtime::new() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping PJRT test: {e:#}");
            None
        }
    }
}

/// Patch the body of section `name` inside a serialized registry, then
/// re-stamp the section CRC in its offset-table row and the trailing
/// index CRC — so the corruption reaches the payload *decoder* instead
/// of being intercepted by the checksum layer.
pub fn patch_section_with_fixed_crcs(bytes: &mut [u8], name: &str, patch: impl Fn(&mut [u8])) {
    let u32_at = |b: &[u8], p: usize| u32::from_le_bytes(b[p..p + 4].try_into().unwrap());
    let u64_at = |b: &[u8], p: usize| u64::from_le_bytes(b[p..p + 8].try_into().unwrap());
    let scheme_len = u32_at(bytes, 8) as usize;
    let entry_cnt = u32_at(bytes, 12 + scheme_len) as usize;
    let mut pos = 16 + scheme_len;
    let mut patched = false;
    for _ in 0..entry_cnt {
        let name_len = u32_at(bytes, pos) as usize;
        let row_name =
            std::str::from_utf8(&bytes[pos + 4..pos + 4 + name_len]).unwrap().to_string();
        let off = u64_at(bytes, pos + 5 + name_len) as usize;
        let len = u64_at(bytes, pos + 13 + name_len) as usize;
        let crc_pos = pos + 21 + name_len;
        if row_name == name {
            patch(&mut bytes[off..off + len]);
            let crc = crc32(&bytes[off..off + len]);
            bytes[crc_pos..crc_pos + 4].copy_from_slice(&crc.to_le_bytes());
            patched = true;
        }
        pos = crc_pos + 4;
    }
    assert!(patched, "section {name:?} not found in index");
    let index_crc = crc32(&bytes[..pos]);
    bytes[pos..pos + 4].copy_from_slice(&index_crc.to_le_bytes());
}

/// Recompute and re-stamp the trailing index CRC after an in-place edit
/// of the header or offset table (adversarial wire tests use this to
/// make corruption reach the semantic validators, not the checksum).
pub fn restamp_index_crc(bytes: &mut [u8]) {
    let u32_at = |b: &[u8], p: usize| u32::from_le_bytes(b[p..p + 4].try_into().unwrap());
    let scheme_len = u32_at(bytes, 8) as usize;
    let entry_cnt = u32_at(bytes, 12 + scheme_len) as usize;
    let mut pos = 16 + scheme_len;
    for _ in 0..entry_cnt {
        let name_len = u32_at(bytes, pos) as usize;
        // name_len u32 + name + kind u8 + offset u64 + length u64 + crc u32.
        pos += 25 + name_len;
    }
    let index_crc = crc32(&bytes[..pos]);
    bytes[pos..pos + 4].copy_from_slice(&index_crc.to_le_bytes());
}

/// Overwrite the header format version (u32 at byte 4) and re-stamp the
/// index CRC — for "right sections, wrong version" adversarial files.
pub fn rewrite_header_version(bytes: &mut [u8], version: u32) {
    bytes[4..8].copy_from_slice(&version.to_le_bytes());
    restamp_index_crc(bytes);
}

/// Exact-f32 checkpoint equality with a labelled panic (Checkpoint
/// PartialEq is exact per-element f32 equality — bitwise for all
/// non-NaN data, and these suites never produce NaN).
pub fn assert_ckpt_bit_eq(got: &Checkpoint, want: &Checkpoint, what: &str) {
    assert_eq!(got, want, "{what}: result diverged from reference");
}

/// True when two checkpoints carry bit-for-bit identical floats (the
/// `to_bits` comparison also distinguishes -0.0 from 0.0, which
/// PartialEq would conflate).
pub fn bits_equal(a: &Checkpoint, b: &Checkpoint) -> bool {
    if a.len() != b.len() {
        return false;
    }
    a.iter().zip(b.iter()).all(|((na, ta), (nb, tb))| {
        na == nb
            && ta.shape() == tb.shape()
            && ta.data().iter().zip(tb.data()).all(|(x, y)| x.to_bits() == y.to_bits())
    })
}

/// Sum over tasks of squared L2 error between exact task vectors and the
/// registry's reconstructions — measured through the serving path.
pub fn registry_sse(reg: &Registry, pre: &Checkpoint, fts: &[Checkpoint]) -> f64 {
    let mut sse = 0.0;
    for (t, ft) in fts.iter().enumerate() {
        let tau = ft.sub(pre).unwrap();
        let d = tau.l2_dist(&reg.load_task_vector(t, &ExecCtx::sequential()).unwrap()).unwrap();
        sse += d * d;
    }
    sse
}
