//! Integration tests over the PJRT runtime: artifact loading, manifest
//! cross-checks, forward/train numerics, and the fused Pallas merged-
//! forward path vs the native Rust implementation.
//!
//! These require `make artifacts` to have produced `artifacts/`.

use anyhow::Result;

use tvq::checkpoint::Checkpoint;
use tvq::data::{VIT_S, VIT_M};
use tvq::quant::{fused, GroupQuantized};
use tvq::runtime::{self, Value};
use tvq::tensor::Tensor;
use tvq::train;
use tvq::util::rng::Rng;

mod common;

/// PJRT is optional in offline builds (the vendored `xla` stub has no
/// client); these tests skip — not fail — when the runtime can't start.
use common::fixtures::runtime;

#[test]
fn index_lists_all_artifacts_and_they_load() {
    let Some(rt) = runtime() else { return };
    let names = rt.available().unwrap();
    assert!(names.len() >= 20, "expected a full artifact set, got {}", names.len());
    // Compile a representative subset (full set is covered by other tests).
    for name in ["vit_s_forward_b32", "vit_s_train_b32", "quantize_4k"] {
        assert!(names.contains(&name.to_string()), "{name} missing from index");
        let art = rt.load(name).unwrap();
        assert_eq!(art.manifest.name, name);
    }
}

#[test]
fn manifest_geometry_matches_presets() {
    let Some(rt) = runtime() else { return };
    for preset in [&VIT_S, &VIT_M] {
        let art = rt
            .load(&format!("{}_forward_b{}", preset.name, preset.eval_batch))
            .unwrap();
        let m = &art.manifest;
        assert_eq!(m.meta_usize("batch"), Some(preset.eval_batch));
        // Input x is the last input: [batch, tokens, token_dim].
        let x = m.inputs.last().unwrap();
        assert_eq!(x.shape, vec![preset.eval_batch, preset.tokens, preset.token_dim]);
        // Output logits [batch, n_classes].
        assert_eq!(m.outputs[0].shape, vec![preset.eval_batch, preset.n_classes]);
    }
}

#[test]
fn forward_is_deterministic_and_shaped() {
    let Some(rt) = runtime() else { return };
    let art = rt.load("vit_s_forward_b8").unwrap();
    let mut rng = Rng::new(42);
    let ck = train::init_vit_checkpoint(&art, &mut rng).unwrap();
    let head = Tensor::randn(&[VIT_S.dim, VIT_S.n_classes], 0.1, &mut rng);
    let x = Tensor::randn(&[8, VIT_S.tokens, VIT_S.token_dim], 1.0, &mut rng);
    let a = runtime::forward_logits(&art, &ck, &head, &x).unwrap();
    let b = runtime::forward_logits(&art, &ck, &head, &x).unwrap();
    assert_eq!(a.shape(), &[8, VIT_S.n_classes]);
    assert_eq!(a, b, "forward must be deterministic");
    assert!(a.data().iter().all(|v| v.is_finite()));
}

#[test]
fn train_step_decreases_loss() -> Result<()> {
    let Some(rt) = runtime() else { return Ok(()) };
    let art = rt.load("vit_s_train_b32")?;
    let mut rng = Rng::new(7);
    let mut ck = train::init_vit_checkpoint(&art, &mut rng)?;
    let head = Tensor::randn(&[VIT_S.dim, VIT_S.n_classes], 0.1, &mut rng);
    // One fixed batch, repeated: loss must fall monotonically-ish.
    let x = Tensor::randn(&[32, VIT_S.tokens, VIT_S.token_dim], 1.0, &mut rng);
    let y: Vec<i32> = (0..32).map(|_| rng.below(VIT_S.n_classes) as i32).collect();
    let yv = Value::I32(vec![32], y);
    let (_, first) = runtime::train_step(&art, &ck, &head, &x, &yv, 0.5)?;
    let mut last = first;
    for _ in 0..20 {
        let (next, loss) = runtime::train_step(&art, &ck, &head, &x, &yv, 0.5)?;
        ck = next;
        last = loss;
    }
    assert!(
        last < first * 0.5,
        "loss should at least halve on a fixed batch: {first} -> {last}"
    );
    Ok(())
}

#[test]
fn pallas_quantize_artifact_matches_native() -> Result<()> {
    // The AOT Pallas quantize kernel and the native rust group quantizer
    // implement the same spec — cross-check them through PJRT.  The
    // artifact takes qmax as an input so one HLO serves every bit width.
    let Some(rt) = runtime() else { return Ok(()) };
    let art = rt.load("quantize_4k")?;
    let n = art.manifest.inputs[0].shape[0];
    let group: usize = art.manifest.meta_usize("block").unwrap();
    let mut rng = Rng::new(11);
    let mut data = vec![0.0f32; n];
    rng.fill_normal(&mut data, 0.02);
    for bits in [2u8, 3, 4, 8] {
        let qmax = (1u32 << bits) - 1;
        let outs = art.execute(&[
            Value::F32(vec![n], data.clone()),
            Value::F32(vec![1], vec![qmax as f32]),
        ])?;
        // outputs: codes [n], scales [g], zps [g]
        let native = GroupQuantized::quantize(&data, bits, group)?;
        let native_codes = native.codes_f32();
        let mut mismatches = 0usize;
        for (a, b) in outs[0].1.iter().zip(&native_codes) {
            // Rounding at the exact .5 boundary may differ by 1 code between
            // XLA's round-to-even and rust's rounding; allow 1.
            if (a - b).abs() > 1.0 + 1e-6 {
                mismatches += 1;
            }
        }
        assert_eq!(mismatches, 0, "{mismatches} code mismatches > 1 at {bits} bits");
        for (a, b) in outs[1].1.iter().zip(&native.scales) {
            assert!(
                (a - b).abs() <= 1e-5 * b.abs().max(1e-12),
                "scale mismatch {a} vs {b} at {bits} bits"
            );
        }
        for (a, b) in outs[2].1.iter().zip(&native.zps) {
            assert!((a - b).abs() <= 1.0 + 1e-6, "zp mismatch {a} vs {b}");
        }
    }
    Ok(())
}

#[test]
fn pallas_dequant_merge_artifact_matches_native() -> Result<()> {
    let Some(rt) = runtime() else { return Ok(()) };
    let art = rt.load("dequant_merge_4k_t8")?;
    let n = art.manifest.inputs[0].shape[0];
    let t = art.manifest.inputs[1].shape[0];
    let group: usize = art.manifest.meta_usize("block").unwrap();
    let bits = 3u8; // codes travel as f32: the artifact is bit-width-agnostic
    let mut rng = Rng::new(13);
    let mut pre = vec![0.0f32; n];
    rng.fill_normal(&mut pre, 0.3);
    let gqs: Vec<GroupQuantized> = (0..t)
        .map(|_| {
            let mut tau = vec![0.0f32; n];
            rng.fill_normal(&mut tau, 0.02);
            GroupQuantized::quantize(&tau, bits, group).unwrap()
        })
        .collect();
    let lams = vec![0.3f32; t];
    // Pallas path.
    let g = n / group;
    let mut q = Vec::new();
    let mut scales = Vec::new();
    let mut zps = Vec::new();
    for gq in &gqs {
        q.extend(gq.codes_f32());
        scales.extend_from_slice(&gq.scales);
        zps.extend_from_slice(&gq.zps);
    }
    let outs = art.execute(&[
        Value::F32(vec![n], pre.clone()),
        Value::F32(vec![t, n], q),
        Value::F32(vec![t, g], scales),
        Value::F32(vec![t, g], zps),
        Value::F32(vec![t], lams.clone()),
    ])?;
    // Native path.
    let refs: Vec<&GroupQuantized> = gqs.iter().collect();
    let mut native = Vec::new();
    fused::dequant_merge_flat(&pre, &refs, &lams, &mut native)?;
    for (i, (a, b)) in outs[0].1.iter().zip(&native).enumerate() {
        assert!(
            (a - b).abs() < 1e-4,
            "merged[{i}] mismatch: pallas {a} vs native {b}"
        );
    }
    Ok(())
}

#[test]
fn pallas_packed_merge_artifact_matches_native() -> Result<()> {
    // The packed-codes kernel (int32 payload, in-kernel unpack) must agree
    // with the native fused path for every supported bit width.
    let Some(rt) = runtime() else { return Ok(()) };
    for bits in [2u8, 4, 8] {
        let art = rt.load(&format!("packed_merge_4k_t8_b{bits}"))?;
        let n = art.manifest.inputs[0].shape[0];
        let t = art.manifest.inputs[1].shape[0];
        let group: usize = art.manifest.meta_usize("block").unwrap();
        let mut rng = Rng::new(19 + bits as u64);
        let mut pre = vec![0.0f32; n];
        rng.fill_normal(&mut pre, 0.3);
        let gqs: Vec<GroupQuantized> = (0..t)
            .map(|_| {
                let mut tau = vec![0.0f32; n];
                rng.fill_normal(&mut tau, 0.02);
                GroupQuantized::quantize(&tau, bits, group).unwrap()
            })
            .collect();
        let lams = vec![0.3f32; t];
        let refs: Vec<&GroupQuantized> = gqs.iter().collect();
        let packed = runtime::packed_merge(&art, &pre, &refs, &lams)?;
        let mut native = Vec::new();
        fused::dequant_merge_flat(&pre, &refs, &lams, &mut native)?;
        for (i, (a, b)) in packed.iter().zip(&native).enumerate() {
            assert!(
                (a - b).abs() < 1e-4,
                "bits {bits} [{i}]: packed {a} vs native {b}"
            );
        }
    }
    Ok(())
}

#[test]
fn merged_forward_artifact_matches_rebuild_then_forward() -> Result<()> {
    // Serving equivalence: running the fused merged-forward artifact must
    // equal materializing the merged checkpoint and running plain forward.
    let Some(rt) = runtime() else { return Ok(()) };
    let art_fused = rt.load("vit_s_merged_forward_t8_b32")?;
    let art_fwd = rt.load("vit_s_forward_b32")?;
    let mut rng = Rng::new(17);
    let pre = train::init_vit_checkpoint(&art_fwd, &mut rng)?;
    let group: usize = art_fused.manifest.meta_usize("block").unwrap();
    let bits = 3u8;
    let n = art_fused.manifest.meta_usize("flat_padded").unwrap();
    let pre_flat = pre.flatten_padded(group);
    assert_eq!(pre_flat.len(), n, "padded flatten must match artifact");
    let t = 8usize;
    let gqs: Vec<GroupQuantized> = (0..t)
        .map(|_| {
            let mut tau = vec![0.0f32; n];
            rng.fill_normal(&mut tau, 0.02);
            GroupQuantized::quantize(&tau, bits, group).unwrap()
        })
        .collect();
    let lams = vec![0.3f32; t];
    let head = Tensor::randn(&[VIT_S.dim, VIT_S.n_classes], 0.1, &mut rng);
    let x = Tensor::randn(&[32, VIT_S.tokens, VIT_S.token_dim], 1.0, &mut rng);

    let refs: Vec<&GroupQuantized> = gqs.iter().collect();
    let fused_logits =
        runtime::merged_forward(&art_fused, &pre_flat, &refs, &lams, &head, &x)?;

    let mut merged_flat = Vec::new();
    fused::dequant_merge_flat(&pre_flat, &refs, &lams, &mut merged_flat)?;
    let merged = pre.unflatten_like(&merged_flat)?;
    let plain_logits = runtime::forward_logits(&art_fwd, &merged, &head, &x)?;

    assert_eq!(fused_logits.shape(), plain_logits.shape());
    for (a, b) in fused_logits.data().iter().zip(plain_logits.data()) {
        assert!((a - b).abs() < 1e-3, "fused {a} vs rebuild {b}");
    }
    Ok(())
}

#[test]
fn pack_params_rejects_wrong_shapes() {
    let Some(rt) = runtime() else { return };
    let art = rt.load("vit_s_forward_b8").unwrap();
    let mut ck = Checkpoint::new();
    ck.insert("bogus", Tensor::zeros(&[3]));
    assert!(runtime::pack_params(&art, &ck).is_err());
}
