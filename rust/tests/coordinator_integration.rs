//! Coordinator integration: the real PJRT backend behind the server —
//! batched serving returns the same logits as a direct forward call, under
//! concurrency, for both shared and per-task merged models.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use tvq::checkpoint::Checkpoint;
use tvq::coordinator::{Server, ServerConfig, ServeModel};
use tvq::data::VIT_S;
use tvq::merge::MergedModel;
use tvq::runtime::{self, Runtime};
use tvq::tensor::Tensor;
use tvq::train;
use tvq::util::rng::Rng;

mod common;

/// PJRT is optional in offline builds (the vendored `xla` stub has no
/// client); tests skip — not fail — when the runtime can't start.
fn make_model(per_task: bool) -> Option<(ServeModel, Checkpoint)> {
    let rt = common::fixtures::runtime()?;
    let art = rt.load("vit_s_forward_b8").unwrap();
    let mut rng = Rng::new(0xC0);
    let ck = train::init_vit_checkpoint(&art, &mut rng).unwrap();
    let n_tasks = 3;
    let merged = if per_task {
        // Distinct per-task variants (EMR-style family).
        MergedModel::PerTask(
            (0..n_tasks)
                .map(|t| {
                    let mut v = ck.clone();
                    for (_, tensor) in v.iter_mut() {
                        for x in tensor.data_mut() {
                            *x += 0.001 * (t as f32 + 1.0);
                        }
                    }
                    v
                })
                .collect(),
        )
    } else {
        MergedModel::Shared(ck.clone())
    };
    let heads: Vec<Tensor> = (0..n_tasks)
        .map(|_| Tensor::randn(&[VIT_S.dim, VIT_S.n_classes], 0.1, &mut rng))
        .collect();
    Some((
        ServeModel { preset: &VIT_S, merged: Arc::new(merged), heads: Arc::new(heads) },
        ck,
    ))
}

fn direct_logits(model: &ServeModel, task: usize, x: &Tensor) -> Vec<f32> {
    // Single-item forward through the b1 artifact (no batching).  One
    // Runtime per thread: PJRT compilation is the expensive part.
    thread_local! {
        static RT: Runtime = Runtime::new().unwrap();
    }
    RT.with(|rt| {
    let art = rt.load("vit_s_forward_b1").unwrap();
    let x1 = Tensor::new(vec![1, VIT_S.tokens, VIT_S.token_dim], x.data().to_vec()).unwrap();
    let logits = runtime::forward_logits(
        &art,
        model.merged.for_task(task),
        &model.heads[task],
        &x1,
    )
    .unwrap();
    logits.data().to_vec()
    })
}

#[test]
fn served_logits_match_direct_forward() -> Result<()> {
    let Some((model, _)) = make_model(false) else { return Ok(()) };
    let server = Server::start(ServerConfig::default(), model.clone())?;
    let mut rng = Rng::new(1);
    for task in 0..3 {
        let x = Tensor::randn(&[VIT_S.tokens, VIT_S.token_dim], 1.0, &mut rng);
        let served = server.infer(task, &x)?;
        let direct = direct_logits(&model, task, &x);
        assert_eq!(served.len(), direct.len());
        for (a, b) in served.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-3, "served {a} vs direct {b}");
        }
    }
    Ok(())
}

#[test]
fn per_task_family_routes_to_the_right_variant() -> Result<()> {
    let Some((model, _)) = make_model(true) else { return Ok(()) };
    let server = Server::start(ServerConfig::default(), model.clone())?;
    let mut rng = Rng::new(2);
    let x = Tensor::randn(&[VIT_S.tokens, VIT_S.token_dim], 1.0, &mut rng);
    let mut outs = Vec::new();
    for task in 0..3 {
        let served = server.infer(task, &x)?;
        let direct = direct_logits(&model, task, &x);
        for (a, b) in served.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-3);
        }
        outs.push(served);
    }
    // Different variants ⇒ different logits (same head index 0 vs 1 uses
    // different heads anyway, so compare variants through task-0's head is
    // unnecessary; distinct outputs suffice as a routing signal).
    assert_ne!(outs[0], outs[1]);
    Ok(())
}

#[test]
fn concurrent_mixed_task_load_is_correct_and_batched() -> Result<()> {
    let Some((model, _)) = make_model(false) else { return Ok(()) };
    let cfg = ServerConfig {
        max_batch: 8,
        max_delay: Duration::from_millis(4),
        queue_cap: 4096,
        executors: 2,
        ..Default::default()
    };
    let server = Arc::new(Server::start(cfg, model.clone())?);
    let model = Arc::new(model);
    let clients = 6usize;
    let per_client = 20usize;
    let mut handles = Vec::new();
    for c in 0..clients {
        let s = server.clone();
        let m = model.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + c as u64);
            for _ in 0..per_client {
                let task = rng.below(3);
                let x = Tensor::randn(&[VIT_S.tokens, VIT_S.token_dim], 1.0, &mut rng);
                let served = s.infer(task, &x).unwrap();
                let direct = direct_logits(&m, task, &x);
                for (a, b) in served.iter().zip(&direct) {
                    assert!((a - b).abs() < 1e-3, "mismatch under load");
                }
            }
        }));
    }
    for h in handles {
        h.join().expect("client panicked");
    }
    let m = server.metrics();
    assert_eq!(m.completed, (clients * per_client) as u64);
    assert_eq!(m.failed, 0);
    assert!(
        m.mean_batch_size > 1.0,
        "expected dynamic batching to group requests (avg {:.2})",
        m.mean_batch_size
    );
    Ok(())
}
