//! End-to-end registry acceptance: an 8-task zoo packed at TVQ-INT4 and
//! RTVQ-B3O2 must
//!
//! 1. measure <= 15% of the f32 `TVQC` zoo bytes on real files,
//! 2. match `StorageReport::ideal` to within a small metadata overhead,
//! 3. round-trip bit-exactly through lazy per-task loads, and
//! 4. feed `ModelCache` a merged variant straight from packed payloads —
//!    with the f32 zoo files *deleted*, proving serving never needs them.

mod common;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use common::fixtures::{drift_zoo, patch_section_with_fixed_crcs, IO_MODES};
use tvq::checkpoint::{Checkpoint, CheckpointStore};
use tvq::coordinator::ModelCache;
use tvq::merge::{MergedModel, Merger, TaskArithmetic};
use tvq::quant::{QuantScheme, QuantizedCheckpoint, Rtvq};
use tvq::registry::{
    build_registry, f32_store_bytes, merge_from_source, DiskAccounting, IoMode, OpenOptions,
    PackedRegistrySource, Registry, TaskVectorSource,
};
use tvq::util::exec::ExecCtx;

const N_TASKS: usize = 8;

/// The suite's standard 8-task common-drift zoo (see
/// [`common::fixtures::drift_zoo`]).
fn zoo(seed: u64) -> (Checkpoint, Vec<Checkpoint>) {
    drift_zoo(N_TASKS, seed)
}

fn tmp(name: &str) -> std::path::PathBuf {
    common::fixtures::tmp("reg_it", name)
}

#[test]
fn packed_registry_meets_table5_storage_budget() {
    let (pre, fts) = zoo(0xACC);
    let dir = tmp("budget");
    std::fs::remove_dir_all(&dir).ok();

    // The f32 baseline: the full fine-tuned zoo as TVQC v1 files.
    let store = CheckpointStore::new(dir.join("f32"));
    for (t, ft) in fts.iter().enumerate() {
        store.save(&format!("task{t:02}"), ft).unwrap();
    }
    let f32_bytes = f32_store_bytes(&store).unwrap();

    for (scheme, max_frac) in
        [(QuantScheme::Tvq(4), 0.15), (QuantScheme::Rtvq(3, 2), 0.15)]
    {
        let path = dir.join(format!("{}.qtvc", scheme.label()));
        let summary = build_registry(&pre, &fts, scheme, &path).unwrap();
        assert_eq!(summary.n_tasks, N_TASKS);
        // Summary bookkeeping matches the real file byte-for-byte.
        let real = std::fs::metadata(&path).unwrap().len();
        assert_eq!(summary.file_bytes, real, "{}: summary vs fs", scheme.label());
        assert_eq!(
            summary.index_bytes + summary.payload_bytes,
            summary.file_bytes
        );

        // Acceptance: <= 15% of the f32 zoo's on-disk bytes.
        let frac = real as f64 / f32_bytes as f64;
        assert!(
            frac <= max_frac,
            "{}: {real} B is {:.1}% of f32 {f32_bytes} B (budget {:.0}%)",
            scheme.label(),
            100.0 * frac,
            100.0 * max_frac
        );

        // Acceptance: matches StorageReport::ideal within metadata
        // overhead (index + affine params + names: < 5% at this size).
        let reg = Registry::open(&path).unwrap();
        let acc = DiskAccounting::measure(&reg).unwrap();
        assert_eq!(acc.params, pre.numel());
        assert!(
            acc.matches_ideal(0.05),
            "{}: file {} vs ideal {} (+{:.2}%)",
            scheme.label(),
            acc.file_bytes,
            acc.ideal_bytes,
            100.0 * acc.overhead_fraction()
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn lazy_loads_are_bit_exact_for_both_schemes() {
    let (pre, fts) = zoo(0xB17E);
    let dir = tmp("bitexact");
    std::fs::remove_dir_all(&dir).ok();

    // TVQ-INT4: every lazily-loaded payload equals in-memory quantization.
    let p_tvq = dir.join("tvq4.qtvc");
    build_registry(&pre, &fts, QuantScheme::Tvq(4), &p_tvq).unwrap();
    let reg = Registry::open(&p_tvq).unwrap();
    assert_eq!(reg.n_tasks(), N_TASKS);
    for (t, ft) in fts.iter().enumerate() {
        let tau = ft.sub(&pre).unwrap();
        let want = QuantizedCheckpoint::quantize(&tau, 4).unwrap();
        match reg.load_task_payload(t).unwrap() {
            tvq::registry::Payload::Checkpoint(got) => {
                assert_eq!(got, want, "task {t}: packed payload not bit-exact")
            }
            other => panic!("unexpected payload {other:?}"),
        }
        assert_eq!(
            reg.load_task_vector(t, &ExecCtx::sequential()).unwrap(),
            want.dequantize().unwrap(),
            "task {t}: dequantized vector not bit-exact"
        );
    }

    // RTVQ-B3O2: lazy base + offset reconstruction equals Algorithm 1.
    let p_rtvq = dir.join("rtvq3o2.qtvc");
    build_registry(&pre, &fts, QuantScheme::Rtvq(3, 2), &p_rtvq).unwrap();
    let reg = Registry::open(&p_rtvq).unwrap();
    assert!(reg.has_rtvq_base());
    let r = Rtvq::quantize(&pre, &fts, 3, 2, true, &ExecCtx::sequential()).unwrap();
    for t in 0..N_TASKS {
        assert_eq!(
            reg.load_task_vector(t, &ExecCtx::sequential()).unwrap(),
            r.dequantize_task(t).unwrap(),
            "task {t}: RTVQ reconstruction not bit-exact"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sparse_sections_fail_closed_even_when_crcs_are_restamped() {
    use tvq::exp::planner::synthetic_planner_zoo;
    use tvq::planner::{build_planned_registry, PlannerConfig};

    let (pre, fts) = synthetic_planner_zoo(3, 0x54A7);
    let dir = tmp("sparse_corrupt");
    std::fs::remove_dir_all(&dir).ok();
    let path = dir.join("sparse.qtvc");
    // Sparse-only candidate set: every task section is kind-4.
    let cfg = PlannerConfig {
        group: 256,
        tvq_bits: vec![],
        rtvq_arms: vec![],
        dare_arms: vec![(75, 3)],
        tall_arms: vec![(25, 4)],
    };
    let profile = tvq::planner::probe(&pre, &fts, &cfg).unwrap();
    let budget = tvq::planner::min_feasible_bytes(&profile) * 2;
    let (plan, _) = build_planned_registry(&pre, &fts, budget, &cfg, &path).unwrap();
    assert!(plan.has_sparse_arms());
    let clean = std::fs::read(&path).unwrap();
    let victim = format!("task00/{}", plan.tensors[0].name);

    // 1. Bitmask bit flipped (CRCs restamped): the decoder's popcount vs
    //    survivor-count cross-check must reject it — only for the
    //    touched task; the others keep serving.
    let mut bad = clean.clone();
    // One bit, so the popcount is guaranteed to move off the header count.
    patch_section_with_fixed_crcs(&mut bad, &victim, |body| body[16] ^= 0x01);
    let p = dir.join("mask_flip.qtvc");
    std::fs::write(&p, &bad).unwrap();
    let reg = Registry::open(&p).unwrap();
    let err = reg.load_task_vector(0, &ExecCtx::sequential()).unwrap_err().to_string();
    assert!(
        err.contains("bitmask/survivor-count mismatch"),
        "mask corruption not caught by the decoder: {err}"
    );
    assert!(
        reg.load_task_vector(1, &ExecCtx::sequential()).is_ok(),
        "untouched task must still serve"
    );

    // 2. Survivor-count header inflated (CRCs restamped): same check,
    //    other direction.
    let mut bad = clean.clone();
    patch_section_with_fixed_crcs(&mut bad, &victim, |body| {
        let n = u64::from_le_bytes(body[8..16].try_into().unwrap());
        body[8..16].copy_from_slice(&(n + 1).to_le_bytes());
    });
    let p = dir.join("count_bump.qtvc");
    std::fs::write(&p, &bad).unwrap();
    assert!(Registry::open(&p).unwrap().load_task_vector(0, &ExecCtx::sequential()).is_err());

    // 3. Dense length shrunk (CRCs restamped): the mask no longer spans
    //    the claimed dense space — truncated-bitmask / geometry checks
    //    must fire, never a scatter out of bounds.
    let mut bad = clean.clone();
    patch_section_with_fixed_crcs(&mut bad, &victim, |body| {
        body[0..8].copy_from_slice(&8u64.to_le_bytes());
    });
    let p = dir.join("dense_shrink.qtvc");
    std::fs::write(&p, &bad).unwrap();
    assert!(Registry::open(&p).unwrap().load_task_vector(0, &ExecCtx::sequential()).is_err());

    // 4. Plain byte flip without restamping: the per-section CRC layer
    //    catches it first (defense in depth).
    let mut bad = clean.clone();
    let n = bad.len();
    bad[n - 5] ^= 0xFF;
    let p = dir.join("crc_flip.qtvc");
    std::fs::write(&p, &bad).unwrap();
    let reg = Registry::open(&p).unwrap();
    let last = reg.n_tasks() - 1;
    let err = reg.load_task_vector(last, &ExecCtx::sequential()).unwrap_err().to_string();
    assert!(err.contains("CRC"), "expected a CRC failure, got: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Kind-5 binary-switch sections must fail closed under adversarial
/// corruption whose CRCs have been re-stamped (so the bytes reach the
/// semantic validators, not the checksum layer) — and `tvq registry
/// verify`, which delegates to this exact read path, must reject every
/// such file with a non-zero exit.
#[test]
fn binary_sections_fail_closed_even_when_crcs_are_restamped() {
    use common::fixtures::{onebit_cfg, pack_planned, rewrite_header_version};

    let dir = tmp("binary_corrupt");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    // OneBit-only candidate set: every task section is kind-5, file is v5.
    let (path, _pre, _fts, plan) =
        pack_planned(&dir, "binary.qtvc", 3, 0x1B17, &onebit_cfg(256));
    assert!(plan.has_onebit_arms());
    assert_eq!(Registry::open(&path).unwrap().version(), 5);
    let clean = std::fs::read(&path).unwrap();
    let victim = format!("task00/{}", plan.tensors[0].name);

    // 1. Group-width header inflated (CRCs restamped): the claimed
    //    logical length outgrows the stored sign bitmap — the decoder's
    //    truncated-bitmap check must reject it, only for the touched
    //    task; the others keep serving.
    let mut bad = clean.clone();
    patch_section_with_fixed_crcs(&mut bad, &victim, |body| {
        let group = u64::from_le_bytes(body[0..8].try_into().unwrap());
        body[0..8].copy_from_slice(&(group * 2).to_le_bytes());
    });
    let p_trunc = dir.join("sign_trunc.qtvc");
    std::fs::write(&p_trunc, &bad).unwrap();
    let reg = Registry::open(&p_trunc).unwrap();
    let err = reg.load_task_vector(0, &ExecCtx::sequential()).unwrap_err().to_string();
    assert!(
        err.contains("truncated sign bitmap") || err.contains("len"),
        "inflated group not caught by the decoder: {err}"
    );
    assert!(
        reg.load_task_vector(1, &ExecCtx::sequential()).is_ok(),
        "untouched task must still serve"
    );

    // 2. Scale-count header inflated (CRCs restamped): the scale table
    //    would overrun the section — the untrusted-count guard or the
    //    scale-table/bitmap length cross-check must fire, never an OOB.
    let mut bad = clean.clone();
    patch_section_with_fixed_crcs(&mut bad, &victim, |body| {
        let n = u64::from_le_bytes(body[8..16].try_into().unwrap());
        body[8..16].copy_from_slice(&(n + 1).to_le_bytes());
    });
    let p_scales = dir.join("scale_bump.qtvc");
    std::fs::write(&p_scales, &bad).unwrap();
    let err = Registry::open(&p_scales)
        .unwrap()
        .load_task_vector(0, &ExecCtx::sequential())
        .unwrap_err()
        .to_string();
    assert!(err.contains("binary payload"), "scale-count corruption escaped: {err}");

    // 3. Kind-5 sections in a file re-labelled v4 (index CRC restamped):
    //    the per-entry kind/version pairing must reject it at open —
    //    binary sections require v5.
    let mut bad = clean.clone();
    rewrite_header_version(&mut bad, 4);
    let p_v4 = dir.join("v4_with_kind5.qtvc");
    std::fs::write(&p_v4, &bad).unwrap();
    let err = Registry::open(&p_v4).unwrap_err().to_string();
    assert!(
        err.contains("v5") || err.contains("binary"),
        "v4 file carrying kind-5 sections was accepted: {err}"
    );

    // 4. `tvq registry verify` is specified to delegate to this read
    //    path: it must accept the clean file and reject every corrupt
    //    one above with a non-zero exit and a pointed stderr.
    let verify = |p: &std::path::Path| {
        std::process::Command::new(env!("CARGO_BIN_EXE_tvq"))
            .args(["registry", "verify"])
            .arg(p)
            .output()
            .expect("spawn tvq registry verify")
    };
    assert!(verify(&path).status.success(), "verify rejected the clean v5 registry");
    for p in [&p_trunc, &p_scales, &p_v4] {
        let out = verify(p);
        assert!(
            !out.status.success(),
            "verify accepted corrupt {}: {}",
            p.display(),
            String::from_utf8_lossy(&out.stdout)
        );
        assert!(
            String::from_utf8_lossy(&out.stderr).contains("error"),
            "verify gave no pointed error for {}",
            p.display()
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The acceptance bar for the zero-copy path: whatever corruption makes
/// `Pread` fail must make `Mmap` fail with the *same* error, lazily, at
/// the same access — never a panic, never a silently-served section.
#[test]
fn mmap_mode_fails_closed_identically_to_pread() {
    let (pre, fts) = zoo(0x33A9);
    let dir = tmp("mmap_failclosed");
    std::fs::remove_dir_all(&dir).ok();
    let path = dir.join("zoo.qtvc");
    build_registry(&pre, &fts, QuantScheme::Tvq(4), &path).unwrap();
    let clean = std::fs::read(&path).unwrap();

    // 1. Payload byte flipped: open succeeds in every mode (lazy), the
    //    touched task fails its per-section CRC with an identical error,
    //    and untouched tasks keep serving.
    let mut bad = clean.clone();
    let n = bad.len();
    bad[n - 3] ^= 0xFF;
    let p = dir.join("payload_flip.qtvc");
    std::fs::write(&p, &bad).unwrap();
    let mut errors = Vec::new();
    for mode in IO_MODES {
        let reg = Registry::open_with(&p, OpenOptions::new().io(mode)).unwrap();
        let last = reg.n_tasks() - 1;
        errors.push(reg.load_task_vector(last, &ExecCtx::sequential()).unwrap_err().to_string());
        assert!(
            reg.load_task_vector(0, &ExecCtx::sequential()).is_ok(),
            "{mode:?}: untouched section must still serve"
        );
    }
    assert!(errors[0].contains("CRC mismatch"), "got: {}", errors[0]);
    assert_eq!(errors[0], errors[1], "mmap vs pread errors diverge");
    assert_eq!(errors[1], errors[2], "pread vs reopen errors diverge");

    // 2. Index byte flipped: open fails in every mode, same error.
    let mut bad = clean.clone();
    bad[20] ^= 0xFF;
    let p = dir.join("index_flip.qtvc");
    std::fs::write(&p, &bad).unwrap();
    let open_errs: Vec<String> = IO_MODES
        .iter()
        .map(|&m| Registry::open_with(&p, OpenOptions::new().io(m)).unwrap_err().to_string())
        .collect();
    assert_eq!(open_errs[0], open_errs[1]);
    assert_eq!(open_errs[1], open_errs[2]);

    // 3. Truncated mid-index: open fails cleanly in every mode.
    let p = dir.join("trunc_index.qtvc");
    std::fs::write(&p, &clean[..24]).unwrap();
    for mode in IO_MODES {
        assert!(Registry::open_with(&p, OpenOptions::new().io(mode)).is_err(), "{mode:?}");
    }

    // 4. Truncated mid-payload: the index rows span past EOF, so open
    //    fails at the bounds check — before any mapping or read.
    let p = dir.join("trunc_payload.qtvc");
    std::fs::write(&p, &clean[..clean.len() - 64]).unwrap();
    for mode in IO_MODES {
        let err = Registry::open_with(&p, OpenOptions::new().io(mode)).unwrap_err().to_string();
        assert!(err.contains("beyond file size"), "{mode:?}: {err}");
    }

    // 5. Empty and sub-header files: clean error in every mode (the
    //    mmap path must not trip over an unmappable zero-length file).
    for (name, bytes) in [("empty.qtvc", &[][..]), ("tiny.qtvc", &clean[..3])] {
        let p = dir.join(name);
        std::fs::write(&p, bytes).unwrap();
        for mode in IO_MODES {
            assert!(
                Registry::open_with(&p, OpenOptions::new().io(mode)).is_err(),
                "{name} under {mode:?}"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Every mode must reconstruct identical bytes — uniform and planned
/// (dense + sparse arms), through both the lazy and the fused serve path.
#[test]
fn all_io_modes_serve_identical_results() {
    use tvq::exp::planner::synthetic_planner_zoo;
    use tvq::planner::{build_planned_registry, fused_merge, PlannerConfig};

    let (pre, fts) = synthetic_planner_zoo(3, 0x10DE);
    let dir = tmp("iomode_equiv");
    std::fs::remove_dir_all(&dir).ok();
    let path = dir.join("planned.qtvc");
    // Full candidate set so dense, RTVQ and sparse arms all appear.
    let cfg = PlannerConfig::default();
    let profile = tvq::planner::probe(&pre, &fts, &cfg).unwrap();
    let budget = tvq::planner::min_feasible_bytes(&profile) * 2;
    build_planned_registry(&pre, &fts, budget, &cfg, &path).unwrap();

    let regs: Vec<Registry> = IO_MODES
        .iter()
        .map(|&m| Registry::open_with(&path, OpenOptions::new().io(m)).unwrap())
        .collect();
    let lams = [0.5f32, 0.2, 0.3];
    let want_fused = fused_merge(&regs[1], &pre, &lams, None, &ExecCtx::sequential()).unwrap();
    for (reg, mode) in regs.iter().zip(IO_MODES) {
        for t in 0..3 {
            assert_eq!(
                reg.load_task_vector(t, &ExecCtx::sequential()).unwrap(),
                regs[1].load_task_vector(t, &ExecCtx::sequential()).unwrap(),
                "{mode:?}: lazy task {t} diverged from pread"
            );
        }
        let fused = fused_merge(reg, &pre, &lams, None, &ExecCtx::sequential()).unwrap();
        assert_eq!(
            fused.l2_dist(&want_fused).unwrap(),
            0.0,
            "{mode:?}: fused merge diverged from pread"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Mapped payload bytes are page cache, not heap: the cache accounting
/// must report them separately and charge only the owned overhead.
#[test]
fn packed_source_reports_mapped_vs_owned_footprint() {
    let (pre, fts) = zoo(0x3A77);
    let dir = tmp("footprint");
    std::fs::remove_dir_all(&dir).ok();
    let path = dir.join("zoo.qtvc");
    build_registry(&pre, &fts, QuantScheme::Rtvq(3, 2), &path).unwrap();

    let source = PackedRegistrySource::open(&path).unwrap();
    let reg = source.registry();
    if reg.io_mode() == IoMode::Mmap {
        assert_eq!(source.mapped_bytes(), reg.file_bytes());
    } else {
        assert_eq!(source.mapped_bytes(), 0);
    }
    // Before any load: only the resident index is owned.
    let cold = source.resident_overhead_bytes();
    assert!(cold >= reg.index_bytes() as usize);
    assert!(
        (cold as u64) < reg.file_bytes(),
        "owned overhead {cold} should be far below the {} file bytes",
        reg.file_bytes()
    );
    // Serving an RTVQ task decodes + caches the shared base: the owned
    // figure must grow by exactly that cache, never by payload bytes.
    source.task_vector(0).unwrap();
    let warm = source.resident_overhead_bytes();
    assert_eq!(warm, cold + pre.fp32_bytes(), "base cache must be the only growth");

    // And the cache rolls those numbers up per source id.
    let cache = ModelCache::new();
    cache.register_source(&source);
    assert_eq!(cache.source_overhead_bytes(), warm);
    assert_eq!(cache.source_mapped_bytes(), source.mapped_bytes());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn model_cache_serves_from_packed_registry_without_f32_zoo() {
    let (pre, fts) = zoo(0x5E2E);
    let dir = tmp("serve");
    std::fs::remove_dir_all(&dir).ok();

    // Reference merge from in-memory dequantized task vectors.
    let ta = TaskArithmetic::default();
    let taus: Vec<Checkpoint> = fts
        .iter()
        .map(|ft| {
            QuantizedCheckpoint::quantize(&ft.sub(&pre).unwrap(), 4)
                .unwrap()
                .dequantize()
                .unwrap()
        })
        .collect();
    let want = ta.merge(&pre, &taus).unwrap();

    // Persist BOTH forms, then delete the f32 zoo before serving.
    let store = CheckpointStore::new(dir.join("f32"));
    for (t, ft) in fts.iter().enumerate() {
        store.save(&format!("task{t:02}"), ft).unwrap();
    }
    let path = dir.join("zoo.qtvc");
    build_registry(&pre, &fts, QuantScheme::Tvq(4), &path).unwrap();
    std::fs::remove_dir_all(dir.join("f32")).unwrap();
    assert!(!dir.join("f32").exists(), "f32 zoo must be gone");

    // The cache builds the variant from packed payloads alone — once,
    // even under concurrent first requests.
    let source = Arc::new(PackedRegistrySource::open(&path).unwrap());
    assert_eq!(source.scheme_label(), "TVQ-INT4");
    let cache = Arc::new(ModelCache::new());
    let builds = Arc::new(AtomicUsize::new(0));
    let mut handles = Vec::new();
    for _ in 0..4 {
        let cache = cache.clone();
        let source = source.clone();
        let builds = builds.clone();
        let pre = pre.clone();
        handles.push(std::thread::spawn(move || {
            cache
                .get_or_build("ta", &source.scheme_label(), || {
                    builds.fetch_add(1, Ordering::SeqCst);
                    merge_from_source(
                        &TaskArithmetic::default(),
                        &pre,
                        source.as_ref(),
                        None,
                        &ExecCtx::default(),
                    )
                })
                .unwrap()
        }));
    }
    let merged: Vec<Arc<MergedModel>> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(builds.load(Ordering::SeqCst), 1, "single-flight violated");
    match (merged[0].as_ref(), &want) {
        (MergedModel::Shared(a), MergedModel::Shared(b)) => {
            assert_eq!(a, b, "packed-registry merge differs from in-memory merge")
        }
        _ => panic!("expected shared merged models"),
    }

    // Subset materialization: merging 3 named tasks touches only those
    // sections and matches the equivalent in-memory subset merge.
    let subset = [1usize, 4, 6];
    let got =
        merge_from_source(&ta, &pre, source.as_ref(), Some(&subset), &ExecCtx::default()).unwrap();
    let sub_taus: Vec<Checkpoint> = subset.iter().map(|&t| taus[t].clone()).collect();
    let want_sub = ta.merge(&pre, &sub_taus).unwrap();
    match (&got, &want_sub) {
        (MergedModel::Shared(a), MergedModel::Shared(b)) => assert_eq!(a, b),
        _ => panic!("expected shared merged models"),
    }

    // Convenience path: merger + source, keyed automatically by the
    // source identity (scheme label qualified with the registry path).
    let via_helper = cache
        .get_or_build_merged(&ta, &pre, source.as_ref())
        .unwrap();
    let want_key = (ta.name().to_string(), source.source_id());
    assert!(
        cache.keys().contains(&want_key),
        "missing cache key {want_key:?}; keys: {:?}",
        cache.keys()
    );
    assert!(source.source_id().starts_with("TVQ-INT4:"));
    match via_helper.as_ref() {
        MergedModel::Shared(_) => {}
        _ => panic!("expected a shared merge"),
    }

    // Two registries at the SAME scheme must not share a cached variant.
    let path2 = dir.join("zoo2.qtvc");
    let (pre2, fts2) = zoo(0xD1FF);
    build_registry(&pre2, &fts2, QuantScheme::Tvq(4), &path2).unwrap();
    let source2 = PackedRegistrySource::open(&path2).unwrap();
    let other = cache.get_or_build_merged(&ta, &pre2, &source2).unwrap();
    assert!(
        !Arc::ptr_eq(&via_helper, &other),
        "different registries at the same scheme shared one cached variant"
    );
    std::fs::remove_dir_all(&dir).ok();
}
