//! Determinism suite for the chunk-parallel decode/merge engine
//! (ISSUE 5 acceptance): for every tested thread count, merged floats,
//! written registry bytes, and chosen plans must be **bit-identical** to
//! the sequential path — parallelism is a pure latency optimization,
//! never a numerics change.
//!
//! Thread counts exercised: 1 (the sequential reference — runs inline on
//! the caller, no workers), 2, and 8 (more workers than work items /
//! shards on some tensors, so the ragged-split edge cases run too).

mod common;

use common::fixtures::{assert_ckpt_bit_eq, het_cfg as cfg, het_zoo as suite, THREADS};
use tvq::merge::{MergedModel, TaskArithmetic};
use tvq::planner::{
    fused_merge, plan_pack_with_pool, probe_with_pool, write_planned_registry_with_pool,
};
use tvq::quant::QuantScheme;
use tvq::registry::{
    build_registry_with_pool, merge_from_source, IoMode, OpenOptions, PackedRegistrySource,
    Registry,
};
use tvq::util::exec::ExecCtx;
use tvq::util::pool::Pool;

fn tmp(name: &str) -> std::path::PathBuf {
    common::fixtures::tmp("pool_det", name)
}

#[test]
fn plans_and_planned_registry_bytes_are_thread_count_invariant() {
    let (pre, fts) = suite(4, 0x5E01);
    let cfg = cfg();
    let dir = tmp("plan");
    std::fs::remove_dir_all(&dir).ok();

    // Probe + solve at every width: identical profiles and plans.
    let seq = Pool::sequential();
    let ref_profile = probe_with_pool(&pre, &fts, &cfg, &seq).unwrap();
    let budget = tvq::planner::min_feasible_bytes(&ref_profile) * 3 / 2;
    let ref_plan = plan_pack_with_pool(&pre, &fts, budget, &cfg, &seq).unwrap();
    assert!(ref_plan.has_sparse_arms(), "suite must exercise kind-4 arms");
    for threads in THREADS {
        let pool = Pool::new(threads);
        let profile = probe_with_pool(&pre, &fts, &cfg, &pool).unwrap();
        for (a, b) in ref_profile.profiles.iter().zip(&profile.profiles) {
            assert_eq!(a.tensor.name, b.tensor.name);
            for (x, y) in a.arms.iter().zip(&b.arms) {
                assert_eq!(x.arm, y.arm, "threads={threads}");
                assert_eq!(x.cost_bytes, y.cost_bytes, "threads={threads}");
                assert_eq!(
                    x.error.to_bits(),
                    y.error.to_bits(),
                    "threads={threads} {}: probed error not bit-identical",
                    a.tensor.name
                );
            }
        }
        let plan = plan_pack_with_pool(&pre, &fts, budget, &cfg, &pool).unwrap();
        assert_eq!(plan, ref_plan, "threads={threads}: chosen plan diverged");
    }

    // Compile the same plan at every width: byte-identical files.
    let ref_path = dir.join("seq.qtvc");
    write_planned_registry_with_pool(&pre, &fts, &ref_plan, &ref_path, &seq).unwrap();
    let ref_bytes = std::fs::read(&ref_path).unwrap();
    for threads in THREADS {
        let pool = Pool::new(threads);
        let path = dir.join(format!("t{threads}.qtvc"));
        write_planned_registry_with_pool(&pre, &fts, &ref_plan, &path, &pool).unwrap();
        assert_eq!(
            std::fs::read(&path).unwrap(),
            ref_bytes,
            "threads={threads}: planned registry bytes diverged"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fused_merge_is_bit_exact_across_thread_counts_and_io_modes() {
    let (pre, fts) = suite(4, 0x5E02);
    let cfg = cfg();
    let dir = tmp("fused");
    std::fs::remove_dir_all(&dir).ok();
    let path = dir.join("zoo.qtvc");
    let seq = Pool::sequential();
    let profile = probe_with_pool(&pre, &fts, &cfg, &seq).unwrap();
    let budget = tvq::planner::min_feasible_bytes(&profile) * 3 / 2;
    let plan = plan_pack_with_pool(&pre, &fts, budget, &cfg, &seq).unwrap();
    assert!(plan.has_sparse_arms(), "fused path must cover sparse scatter shards");
    write_planned_registry_with_pool(&pre, &fts, &plan, &path, &seq).unwrap();

    let lams = [0.4f32, 0.1, 0.3, 0.2];
    for mode in [IoMode::Mmap, IoMode::Pread] {
        let reg = Registry::open_with(&path, OpenOptions::new().io(mode)).unwrap();
        let want = fused_merge(&reg, &pre, &lams, None, &ExecCtx::with_pool(&seq)).unwrap();
        let want_sub =
            fused_merge(&reg, &pre, &[0.4, 0.3], Some(&[0, 2]), &ExecCtx::with_pool(&seq)).unwrap();
        for threads in THREADS {
            let pool = Pool::new(threads);
            let got = fused_merge(&reg, &pre, &lams, None, &ExecCtx::with_pool(&pool)).unwrap();
            assert_ckpt_bit_eq(&got, &want, &format!("fused merge {mode:?} threads={threads}"));
            let ctx = ExecCtx::with_pool(&pool);
            let got_sub =
                fused_merge(&reg, &pre, &[0.4, 0.3], Some(&[0, 2]), &ctx).unwrap();
            assert_ckpt_bit_eq(
                &got_sub,
                &want_sub,
                &format!("fused subset merge {mode:?} threads={threads}"),
            );
        }
    }

    // Lazy per-task reconstruction rides the same shards.
    let reg = Registry::open(&path).unwrap();
    for t in 0..fts.len() {
        let want = reg.load_task_vector(t, &ExecCtx::with_pool(&seq)).unwrap();
        for threads in THREADS {
            let pool = Pool::new(threads);
            let got = reg.load_task_vector(t, &ExecCtx::with_pool(&pool)).unwrap();
            assert_ckpt_bit_eq(&got, &want, &format!("lazy task {t} threads={threads}"));
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn uniform_registry_build_bytes_are_thread_count_invariant() {
    let (pre, fts) = suite(5, 0x5E03);
    let dir = tmp("build");
    std::fs::remove_dir_all(&dir).ok();
    for scheme in [QuantScheme::Tvq(3), QuantScheme::Rtvq(3, 2)] {
        let seq_path = dir.join(format!("{}_t1.qtvc", scheme.label()));
        build_registry_with_pool(&pre, &fts, scheme, &seq_path, &Pool::sequential()).unwrap();
        let want = std::fs::read(&seq_path).unwrap();
        for threads in THREADS {
            let pool = Pool::new(threads);
            let path = dir.join(format!("{}_t{threads}.qtvc", scheme.label()));
            build_registry_with_pool(&pre, &fts, scheme, &path, &pool).unwrap();
            assert_eq!(
                std::fs::read(&path).unwrap(),
                want,
                "{}: threads={threads} wrote different bytes",
                scheme.label()
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn packed_source_merge_is_bit_exact_across_thread_counts() {
    let (pre, fts) = suite(5, 0x5E04);
    let dir = tmp("merge_src");
    std::fs::remove_dir_all(&dir).ok();
    let path = dir.join("zoo.qtvc");
    build_registry_with_pool(&pre, &fts, QuantScheme::Tvq(4), &path, &Pool::sequential())
        .unwrap();
    let src = PackedRegistrySource::open(&path).unwrap();
    let ta = TaskArithmetic::default();
    let seq = Pool::sequential();
    // All tasks (across-task fan-out) and a single task (within-task
    // fan-out) both reduce to the sequential floats exactly.
    for tasks in [None, Some(&[2usize][..]), Some(&[0usize, 3][..])] {
        let want = merge_from_source(&ta, &pre, &src, tasks, &ExecCtx::with_pool(&seq)).unwrap();
        for threads in THREADS {
            let pool = Pool::new(threads);
            let got =
                merge_from_source(&ta, &pre, &src, tasks, &ExecCtx::with_pool(&pool)).unwrap();
            match (&got, &want) {
                (MergedModel::Shared(a), MergedModel::Shared(b)) => assert_ckpt_bit_eq(
                    a,
                    b,
                    &format!("packed merge tasks={tasks:?} threads={threads}"),
                ),
                _ => panic!("expected shared merges"),
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}
