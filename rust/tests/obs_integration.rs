//! Observability suite (ISSUE 7 acceptance):
//!
//! * `{"cmd": "watch"}` streams ≥2 incremental NDJSON delta frames to a
//!   raw TCP client, and the client disconnecting ends the stream
//!   without wedging the front-end.
//! * A traced run touching registry / merge / cache / control layers
//!   exports Chrome trace-event JSON that reparses with `util::json`
//!   and contains spans from all four categories.
//! * `{"cmd": "status"}` carries the derived observability fields
//!   (histogram quantiles, merge-build speedup, pool busy spread).
//!
//! The suite is already smoke-sized; `TVQ_SMOKE=1` changes nothing.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;
use tvq::coordinator::control::{ControlPlane, VariantConfig, VariantState};
use tvq::coordinator::server::Backend;
use tvq::coordinator::{ModelCache, Server, ServerConfig, TcpFront};
use tvq::data::VIT_S;
use tvq::exp::planner::synthetic_planner_zoo;
use tvq::merge::TaskArithmetic;
use tvq::registry::{PackedRegistrySource, Registry};
use tvq::tensor::Tensor;
use tvq::util::exec::ExecCtx;
use tvq::util::json::Json;

mod common;

struct EchoBackend;
impl Backend for EchoBackend {
    fn infer(&mut self, task: usize, x: &Tensor, n: usize) -> Result<Vec<Vec<f32>>> {
        let img = x.numel() / x.shape()[0];
        Ok((0..n).map(|i| vec![x.data()[i * img], task as f32]).collect())
    }
}

fn start_front() -> (TcpFront, Arc<Server>) {
    let server = Arc::new(
        Server::start_with_backend(ServerConfig::default(), &VIT_S, 4, || Ok(EchoBackend))
            .unwrap(),
    );
    let front = TcpFront::bind("127.0.0.1:0", server.clone(), 8).unwrap();
    (front, server)
}

fn infer_line(task: usize) -> String {
    let n = VIT_S.tokens * VIT_S.token_dim;
    format!(r#"{{"task": {task}, "x": [{}]}}"#, vec!["0.5"; n].join(","))
}

fn roundtrip(addr: std::net::SocketAddr, line: &str) -> String {
    let mut conn = TcpStream::connect(addr).unwrap();
    writeln!(conn, "{line}").unwrap();
    let mut reply = String::new();
    BufReader::new(conn).read_line(&mut reply).unwrap();
    reply
}

fn tmpdir(tag: &str) -> PathBuf {
    common::fixtures::tmpdir("obs", tag)
}

fn pack(dir: &Path, name: &str, seed: u64) -> PathBuf {
    common::fixtures::pack_tvq4(dir, name, 3, seed).0
}

#[test]
fn watch_streams_incremental_frames_then_disconnects_cleanly() {
    let (mut front, _server) = start_front();
    // One request up front so the first frame carries real totals.
    let reply = roundtrip(front.addr(), &infer_line(1));
    assert!(reply.contains("logits"), "reply: {reply}");

    let mut conn = TcpStream::connect(front.addr()).unwrap();
    writeln!(conn, r#"{{"cmd": "watch", "interval_ms": 20}}"#).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut frames = Vec::new();
    for i in 0..3 {
        let mut line = String::new();
        let n = reader.read_line(&mut line).unwrap();
        assert!(n > 0, "stream ended before frame {i}");
        frames.push(Json::parse(line.trim()).unwrap());
    }
    assert!(frames.len() >= 2, "need at least two incremental frames");
    for (i, f) in frames.iter().enumerate() {
        assert_eq!(f.req("seq").unwrap().as_usize().unwrap(), i, "frame {i} out of order");
        assert!(f.req("server").unwrap().get("latency_p50_us").is_some());
    }
    // Frame 0 reports totals so far; later frames report pure deltas.
    let completed = |f: &Json| f.req("server").unwrap().req("completed").unwrap().as_usize();
    assert_eq!(completed(&frames[0]).unwrap(), 1);
    assert_eq!(completed(&frames[1]).unwrap(), 0);

    // Client disconnect ends the watch without wedging the front-end:
    // a fresh connection still gets served.
    drop(reader);
    drop(conn);
    let reply = roundtrip(front.addr(), &infer_line(2));
    assert!(reply.contains("logits"), "post-watch reply: {reply}");
    front.shutdown();
}

#[test]
fn traced_run_exports_chrome_json_covering_four_categories() {
    let dir = tmpdir("trace");
    let path = pack(&dir, "zoo.qtvc", 11);

    tvq::obs::trace::clear();
    tvq::obs::trace::enable();

    // Registry spans: open + section reads.
    let reg = Registry::open(&path).unwrap();
    reg.load_task_vector(0, &ExecCtx::sequential()).unwrap();

    // Merge + cache spans: a fused merge built through the model cache.
    let (pre, _fts) = synthetic_planner_zoo(3, 11);
    let cache = Arc::new(ModelCache::new());
    let source = PackedRegistrySource::open(&path).unwrap();
    cache.get_or_build_merged(&TaskArithmetic::default(), &pre, &source).unwrap();

    // Control spans: variant lifecycle (load/admit/service/drain).
    let plane = ControlPlane::new(Arc::new(ModelCache::new()));
    let variant = plane.load_variant("zoo", &path, &VariantConfig::default()).unwrap();
    let rx = variant.submit_task_vector(0).unwrap();
    rx.recv().unwrap().unwrap();
    plane.drain_variant("zoo", None).unwrap();
    assert!(variant.await_state(&VariantState::Terminated, std::time::Duration::from_secs(10)));

    tvq::obs::trace::disable();
    let out = dir.join("trace.json");
    tvq::obs::trace::export_to_file(out.to_str().unwrap()).unwrap();

    // The exported file must reparse with our own JSON parser and carry
    // complete events from all four instrumented layers.
    let text = std::fs::read_to_string(&out).unwrap();
    let parsed = Json::parse(&text).unwrap();
    let events = parsed.req("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "trace exported no events");
    let mut cats = std::collections::BTreeSet::new();
    for ev in events {
        assert_eq!(ev.req("ph").unwrap().as_str().unwrap(), "X");
        assert!(ev.req("ts").unwrap().as_f64().unwrap() >= 0.0);
        assert!(ev.req("dur").unwrap().as_f64().unwrap() >= 0.0);
        cats.insert(ev.req("cat").unwrap().as_str().unwrap().to_string());
    }
    for needed in ["registry", "merge", "cache", "control"] {
        assert!(cats.contains(needed), "missing category {needed:?}; saw {cats:?}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn status_json_carries_quantiles_and_speedup() {
    let (front, _server) = start_front();
    for t in 0..4 {
        let reply = roundtrip(front.addr(), &infer_line(t));
        assert!(reply.contains("logits"), "reply: {reply}");
    }
    let reply = roundtrip(front.addr(), r#"{"cmd": "status"}"#);
    let parsed = Json::parse(reply.trim()).unwrap();
    let server = parsed.req("server").unwrap();
    assert_eq!(server.req("completed").unwrap().as_usize().unwrap(), 4);
    assert!(server.req("latency_p50_us").unwrap().as_f64().unwrap() > 0.0);
    assert!(server.req("latency_p99_us").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(server.req("latency_count").unwrap().as_usize().unwrap(), 4);
    // Present even when zero: one schema for the status payload.
    assert!(server.req("merge_build_speedup").unwrap().as_f64().unwrap() >= 0.0);
    assert!(server.req("queue_wait_us").unwrap().get("p50").is_some());
    assert!(server.req("pool").unwrap().get("workers").is_some());
}
