//! Sharded-registry suite (ISSUE 9 acceptance): `MANIFEST.qtvm` +
//! tiered section fetch.
//!
//! * Sharding a planned zoo with duplicated deltas dedups byte-identical
//!   section bodies, and the sharded footprint undercuts the monolithic
//!   file.
//! * `fused_merge` and per-task decodes over the sharded store are
//!   bit-identical to the single-file registry at every thread count,
//!   whether chunks arrive from tier 0 (local shard mmap) or tier 1 (a
//!   live TCP fetch-server with an LRU chunk cache).
//! * Routed merges through [`ShardedSource`] match the monolithic
//!   [`PackedRegistrySource`] path bit-for-bit.
//! * Fail-closed: a missing shard file, a CRC-corrupt chunk, a
//!   content-hash (aliasing) mismatch and a truncated paged index all
//!   error — with the *same* message on both tiers, because every check
//!   runs client-side against the client's manifest.
//! * [`GenerationalManifest`] swaps a manifest atomically: a pinned
//!   generation keeps serving its original shard inodes bit-exact while
//!   the published generation serves the new zoo.
//!
//! `TVQ_SMOKE=1` shrinks the thread sweep, never the assertions.

use std::path::{Path, PathBuf};
use std::sync::Arc;

mod common;

use common::fixtures::{assert_ckpt_bit_eq, bits_equal, shard_zoo, smoke};
use tvq::checkpoint::Checkpoint;
use tvq::coordinator::router::MergeSpec;
use tvq::coordinator::{GenerationalManifest, ModelCache, SectionFetchPool, TcpFront};
use tvq::planner::fused_merge;
use tvq::registry::{
    Manifest, ManifestRow, OpenOptions, PackedRegistrySource, Registry, SectionScratch,
    ShardOptions, ShardedRegistry, ShardedSource,
};
use tvq::util::crc32;
use tvq::util::exec::ExecCtx;
use tvq::util::pool::Pool;

const N_TASKS: usize = 3;

fn tmpdir(tag: &str) -> PathBuf {
    common::fixtures::tmpdir("shardreg", tag)
}

fn opts2() -> ShardOptions {
    ShardOptions { n_shards: 2, ..ShardOptions::default() }
}

/// Thread widths for the determinism sweeps (smoke drops the widest).
fn threads() -> &'static [usize] {
    if smoke() {
        &[1, 2]
    } else {
        &[1, 2, 8]
    }
}

/// Sequentially decoded per-task baselines from the monolithic file.
fn baselines(path: &Path, n_tasks: usize) -> Vec<Checkpoint> {
    let reg = Registry::open(path).unwrap();
    let ctx = ExecCtx::sequential();
    (0..n_tasks).map(|t| reg.load_task_vector(t, &ctx).unwrap()).collect()
}

/// Serve `manifest` over a loopback fetch-server and open a tier-1
/// registry against it.  The front must outlive the registry's reads.
fn open_tier1(manifest: &Path) -> (TcpFront, ShardedRegistry) {
    let pool = Arc::new(SectionFetchPool::open(manifest, 2).unwrap());
    let front = TcpFront::bind_sections("127.0.0.1:0", pool, 8).unwrap();
    let reg = ShardedRegistry::open_remote(
        manifest,
        &front.addr().to_string(),
        32 << 20,
        OpenOptions::default(),
    )
    .unwrap();
    (front, reg)
}

/// First task-payload row (name `task/tensor`, not `__base__/...`) of
/// the manifest, plus its `(task, tensor)` indices in the plan.
fn first_task_row(manifest: &Path) -> (ManifestRow, usize, usize) {
    let m = Manifest::read(manifest).unwrap();
    for p in 0..m.pages().len() {
        for row in m.read_page(manifest, p).unwrap() {
            let Some((task, tensor)) = row.name.split_once('/') else { continue };
            let Some(t) = m.plan().task_names.iter().position(|n| n == task) else { continue };
            let l = m
                .plan()
                .tensors
                .iter()
                .position(|tn| tn.name == tensor)
                .expect("row tensor must be in the plan");
            return (row, t, l);
        }
    }
    panic!("manifest has no task rows");
}

#[test]
fn sharding_dedups_identical_sections_below_monolithic_bytes() {
    let dir = tmpdir("dedup");
    let (path, manifest, _pre, _fts, summary) = shard_zoo(&dir, N_TASKS, 11, &opts2());
    assert!(
        summary.n_dedup_hits > 0,
        "task 1 clones task 0, so at least its sections must alias existing chunks"
    );
    assert_eq!(summary.n_sections, summary.n_unique_chunks + summary.n_dedup_hits);
    assert!(
        summary.total_bytes() < summary.source_bytes,
        "dedup must beat the monolithic file: {} sharded vs {} monolithic",
        summary.total_bytes(),
        summary.source_bytes
    );

    // The cloned task round-trips to the same floats through the alias.
    let base = baselines(&path, N_TASKS);
    let sharded = ShardedRegistry::open(&manifest).unwrap();
    assert_eq!(sharded.n_tasks(), N_TASKS);
    let ctx = ExecCtx::sequential();
    for (t, want) in base.iter().enumerate() {
        let got = sharded.load_task_vector(t, &ctx).unwrap();
        assert_ckpt_bit_eq(&got, want, &format!("sharded decode of task {t}"));
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn round_trip_is_bit_exact_across_tiers_and_threads() {
    let dir = tmpdir("roundtrip");
    let (path, manifest, pre, _fts, _summary) = shard_zoo(&dir, N_TASKS, 13, &opts2());
    let base = baselines(&path, N_TASKS);
    let mono = Registry::open(&path).unwrap();
    let lams = [0.35f32, -0.2, 0.4];
    let want = fused_merge(&mono, &pre, &lams, None, &ExecCtx::sequential()).unwrap();
    let want_sub =
        fused_merge(&mono, &pre, &[0.5, 0.25], Some(&[0, 2]), &ExecCtx::sequential()).unwrap();

    let tier0 = ShardedRegistry::open(&manifest).unwrap();
    let (mut front, tier1) = open_tier1(&manifest);
    for (tier, reg) in [("tier0", &tier0), ("tier1", &tier1)] {
        for &width in threads() {
            let pool = Pool::new(width);
            let ctx = ExecCtx::with_pool(&pool);
            let got = fused_merge(reg, &pre, &lams, None, &ctx).unwrap();
            assert_ckpt_bit_eq(&got, &want, &format!("fused merge {tier} threads={width}"));
            let got_sub = fused_merge(reg, &pre, &[0.5, 0.25], Some(&[0, 2]), &ctx).unwrap();
            assert_ckpt_bit_eq(
                &got_sub,
                &want_sub,
                &format!("subset fused merge {tier} threads={width}"),
            );
            for (t, want_t) in base.iter().enumerate() {
                let got_t = reg.load_task_vector(t, &ctx).unwrap();
                assert_ckpt_bit_eq(
                    &got_t,
                    want_t,
                    &format!("task {t} {tier} threads={width}"),
                );
            }
        }
    }
    let (hits, misses) = tier1.cache_stats();
    assert!(hits > 0, "repeated tier-1 reads must hit the chunk cache");
    assert!(misses > 0, "first tier-1 reads must miss the chunk cache");
    front.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn routed_merge_over_sharded_source_matches_single_file() {
    let dir = tmpdir("routed");
    let (path, manifest, pre, _fts, _summary) = shard_zoo(&dir, N_TASKS, 17, &opts2());
    let spec = MergeSpec::new(&[0, 2], &[0.4, 0.25]).unwrap();

    let mono = PackedRegistrySource::open(&path).unwrap();
    let want = ModelCache::new().get_or_merge_routed(&spec, &pre, &mono).unwrap();

    let tier0 = ShardedSource::new(Arc::new(ShardedRegistry::open(&manifest).unwrap()));
    let (mut front, remote) = open_tier1(&manifest);
    let tier1 = ShardedSource::new(Arc::new(remote));
    for (name, src) in [("tier0", &tier0), ("tier1", &tier1)] {
        let got = ModelCache::new().get_or_merge_routed(&spec, &pre, src).unwrap();
        assert!(
            bits_equal(got.for_task(0), want.for_task(0)),
            "routed merge over {name} sharded source diverged from single-file"
        );
    }
    front.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn missing_shard_file_fails_closed_identically_across_tiers() {
    let dir = tmpdir("missing");
    let (_path, manifest, _pre, _fts, summary) = shard_zoo(&dir, N_TASKS, 19, &opts2());

    // Open everything lazily first (no reads), then pull a shard out.
    let tier0 = ShardedRegistry::open(&manifest).unwrap();
    let (mut front, tier1) = open_tier1(&manifest);
    std::fs::remove_file(&summary.shard_paths[0]).unwrap();

    let ctx = ExecCtx::sequential();
    let probe = |reg: &ShardedRegistry| -> String {
        for t in 0..N_TASKS {
            if let Err(e) = reg.load_task_vector(t, &ctx) {
                return format!("{e:#}");
            }
        }
        panic!("a zoo missing a shard file must fail some task decode");
    };
    let e0 = probe(&tier0);
    let e1 = probe(&tier1);
    assert!(e0.contains("is missing"), "tier-0 error names the cause: {e0}");
    assert_eq!(e0, e1, "tiers must fail closed with the same error");
    front.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn crc_corrupt_chunk_fails_closed_identically_across_tiers() {
    let dir = tmpdir("crc");
    let (_path, manifest, _pre, _fts, summary) = shard_zoo(&dir, N_TASKS, 23, &opts2());
    let (row, t, l) = first_task_row(&manifest);

    // Flip one payload byte on disk before anything maps the shard.  The
    // fetch-server serves the corrupt bytes blindly; detection is the
    // *client's* job on both tiers.
    let shard_path = &summary.shard_paths[row.chunk.shard as usize];
    let mut bytes = std::fs::read(shard_path).unwrap();
    bytes[(row.chunk.offset + row.chunk.length / 2) as usize] ^= 0xFF;
    std::fs::write(shard_path, &bytes).unwrap();

    let tier0 = ShardedRegistry::open(&manifest).unwrap();
    let (mut front, tier1) = open_tier1(&manifest);
    let mut scratch = SectionScratch::default();
    let e0 = format!("{:#}", tier0.planned_task_view(t, l, &mut scratch).unwrap_err());
    let e1 = format!("{:#}", tier1.planned_task_view(t, l, &mut scratch).unwrap_err());
    assert!(e0.contains("CRC mismatch"), "tier-0 error names the cause: {e0}");
    assert_eq!(e0, e1, "tiers must fail closed with the same error");
    front.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Flip one byte of `name`'s content hash inside its manifest page, then
/// re-stamp the page CRC in the directory and the trailing index CRC —
/// so the corruption reaches the chunk verifier, not the checksum layer.
fn corrupt_row_hash(manifest: &Path, name: &str) {
    let m = Manifest::read(manifest).unwrap();
    let pg = m.pages()[m.page_for(name).unwrap()].clone();
    let mut bytes = std::fs::read(manifest).unwrap();
    let (start, end) = (pg.offset as usize, (pg.offset + pg.length) as usize);
    let mut pos = start;
    loop {
        assert!(pos < end, "row {name:?} not found in its page");
        let name_len =
            u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let row_name = std::str::from_utf8(&bytes[pos + 4..pos + 4 + name_len]).unwrap();
        // Fixed row tail: kind u8, shard u32, offset u64, length u64,
        // crc u32, hash u64 = 33 bytes.
        let tail = pos + 4 + name_len;
        if row_name == name {
            bytes[tail + 25] ^= 0xFF;
            break;
        }
        pos = tail + 33;
    }
    let page_crc = crc32(&bytes[start..end]);
    // The directory entry is `first str, rows u32, offset u64,
    // length u64, crc u32`; locate it by its unique offset+length pair.
    let header_end = m.header_bytes() as usize;
    let mut pat = Vec::with_capacity(16);
    pat.extend_from_slice(&pg.offset.to_le_bytes());
    pat.extend_from_slice(&pg.length.to_le_bytes());
    let at = bytes[..header_end - 4]
        .windows(16)
        .position(|w| w == &pat[..])
        .expect("page directory entry");
    bytes[at + 16..at + 20].copy_from_slice(&page_crc.to_le_bytes());
    let index_crc = crc32(&bytes[..header_end - 4]);
    bytes[header_end - 4..header_end].copy_from_slice(&index_crc.to_le_bytes());
    std::fs::write(manifest, &bytes).unwrap();
}

#[test]
fn content_hash_mismatch_fails_closed_identically_across_tiers() {
    let dir = tmpdir("hash");
    let (_path, manifest, _pre, _fts, _summary) = shard_zoo(&dir, N_TASKS, 29, &opts2());
    let (row, t, l) = first_task_row(&manifest);
    corrupt_row_hash(&manifest, &row.name);

    let tier0 = ShardedRegistry::open(&manifest).unwrap();
    let (mut front, tier1) = open_tier1(&manifest);
    let mut scratch = SectionScratch::default();
    let e0 = format!("{:#}", tier0.planned_task_view(t, l, &mut scratch).unwrap_err());
    let e1 = format!("{:#}", tier1.planned_task_view(t, l, &mut scratch).unwrap_err());
    assert!(e0.contains("content-hash mismatch"), "tier-0 error names the cause: {e0}");
    assert_eq!(e0, e1, "tiers must fail closed with the same error");
    front.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_paged_index_fails_closed() {
    let dir = tmpdir("trunc");
    let (_path, manifest, _pre, _fts, _summary) = shard_zoo(&dir, N_TASKS, 31, &opts2());

    // Lazy opens read the header + directory only; truncate the page
    // bodies out from under them afterwards.
    let tier0 = ShardedRegistry::open(&manifest).unwrap();
    let (mut front, tier1) = open_tier1(&manifest);
    let header_bytes = Manifest::read(&manifest).unwrap().header_bytes();
    let f = std::fs::OpenOptions::new().write(true).open(&manifest).unwrap();
    f.set_len(header_bytes).unwrap();
    drop(f);

    let ctx = ExecCtx::sequential();
    let e0 = format!("{:#}", tier0.load_task_vector(0, &ctx).unwrap_err());
    let e1 = format!("{:#}", tier1.load_task_vector(0, &ctx).unwrap_err());
    assert!(e0.contains("truncated QTVM index page"), "lazy page read names the cause: {e0}");
    assert_eq!(e0, e1, "tiers must fail closed with the same error");

    // A fresh open sees the page spans fall outside the file and refuses.
    let e = format!("{:#}", ShardedRegistry::open(&manifest).unwrap_err());
    assert!(e.contains("outside the manifest"), "fresh open fails closed: {e}");
    front.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn generational_manifest_swap_pins_old_shards_and_serves_new() {
    let dir_a = tmpdir("swap_a");
    let dir_b = tmpdir("swap_b");
    let (path_a, manifest_a, _pre_a, _fts_a, _sa) = shard_zoo(&dir_a, N_TASKS, 37, &opts2());
    let (path_b, _manifest_b, _pre_b, _fts_b, sb) = shard_zoo(&dir_b, N_TASKS, 41, &opts2());
    let base_a = baselines(&path_a, N_TASKS);
    let base_b = baselines(&path_b, N_TASKS);

    let gm = GenerationalManifest::open(&manifest_a).unwrap();
    let g1 = gm.pin();
    let ctx = ExecCtx::sequential();
    // Decode every task now so generation 1 maps every shard inode.
    for (t, want) in base_a.iter().enumerate() {
        let got = g1.registry().load_task_vector(t, &ctx).unwrap();
        assert_ckpt_bit_eq(&got, want, &format!("gen-1 task {t} before swap"));
    }

    // Stage zoo B over zoo A's directory: shard files land under their
    // manifest-recorded names via write-to-temp + rename, so generation
    // 1's mapped inodes survive the directory-entry swap untouched.
    for shard in &sb.shard_paths {
        let name = shard.file_name().unwrap();
        let tmp = dir_a.join("incoming.tmpswap");
        std::fs::write(&tmp, std::fs::read(shard).unwrap()).unwrap();
        std::fs::rename(&tmp, dir_a.join(name)).unwrap();
    }
    std::fs::copy(&sb.manifest_path, gm.stage_path()).unwrap();
    let published = gm.publish_staged().unwrap();
    assert_eq!(published, g1.number() + 1, "publish bumps the generation number");

    let g2 = gm.pin();
    assert_eq!(g2.number(), published);
    for (t, want) in base_b.iter().enumerate() {
        let got = g2.registry().load_task_vector(t, &ctx).unwrap();
        assert_ckpt_bit_eq(&got, want, &format!("gen-2 task {t} after swap"));
    }
    // The superseded generation still serves zoo A bit-exact from its
    // pinned inodes — shard immutability is what makes the swap safe.
    for (t, want) in base_a.iter().enumerate() {
        let got = g1.registry().load_task_vector(t, &ctx).unwrap();
        assert_ckpt_bit_eq(&got, want, &format!("gen-1 task {t} after swap"));
    }
    let live = gm.live_generations();
    assert!(
        live.contains(&g1.number()) && live.contains(&g2.number()),
        "both pinned generations stay live: {live:?}"
    );
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
}

/// The PR-9 API collapse keeps the `*_with_pool` twins as thin shims;
/// they must stay bit-identical to the canonical [`ExecCtx`] entry
/// points until they are removed.
#[test]
#[allow(deprecated)]
fn deprecated_pool_shims_match_canonical_entry_points() {
    use tvq::planner::fused_merge_with_pool;
    use tvq::registry::IoMode;

    let dir = tmpdir("shims");
    let (path, _manifest, pre, _fts, _summary) = shard_zoo(&dir, N_TASKS, 43, &opts2());
    let pool = Pool::new(2);
    let reg = Registry::open_with_io(&path, IoMode::Pread).unwrap();
    let canon = Registry::open_with(&path, OpenOptions::new().io(IoMode::Pread)).unwrap();
    assert_eq!(reg.io_mode(), canon.io_mode(), "open shim matches OpenOptions");

    let lams = [0.3f32, 0.1, -0.2];
    let want = fused_merge(&canon, &pre, &lams, None, &ExecCtx::with_pool(&pool)).unwrap();
    let got = fused_merge_with_pool(&reg, &pre, &lams, None, &pool).unwrap();
    assert_ckpt_bit_eq(&got, &want, "fused_merge_with_pool shim");

    let via_shim = reg.load_task_vector_with_pool(1, &pool).unwrap();
    let via_ctx = canon.load_task_vector(1, &ExecCtx::with_pool(&pool)).unwrap();
    assert_ckpt_bit_eq(&via_shim, &via_ctx, "load_task_vector_with_pool shim");
    std::fs::remove_dir_all(&dir).ok();
}
