//! Cross-module property tests: the paper's equations as checked
//! invariants over randomized inputs (quantizer error bound Eq. 3, RTVQ
//! decomposition Eq. 4-5, merge-method algebra, packing round-trips).

use tvq::checkpoint::Checkpoint;
use tvq::merge::{EmrMerging, Individual, MergedModel, Merger, TaskArithmetic};
use tvq::quant::{
    fused, AffineParams, BitPacked, GroupQuantized, QuantScheme, QuantizedCheckpoint, Rtvq,
};
use tvq::registry::container::{decode_checkpoint_payload, encode_checkpoint_payload};
use tvq::tensor::Tensor;
use tvq::util::exec::ExecCtx;
use tvq::util::prop::{check, gen_vec, Config};
use tvq::util::rng::Rng;

mod common;

use common::fixtures::rand_ck;

#[test]
fn prop_affine_error_bound_eq3() {
    // |x - dq(q(x))| <= Delta/2 for every in-range value (Eq. 3).
    check(
        Config { cases: 128, seed: 0xE43 },
        |rng| {
            let bits = 1 + rng.below(8) as u8;
            let v = gen_vec(rng, 300, 0.1);
            (bits, v)
        },
        |(bits, v)| {
            let p = AffineParams::from_slice(v, *bits).map_err(|e| e.to_string())?;
            let bound = p.error_bound() as f64 + 1e-7;
            for &x in v {
                let xhat = p.dequantize_code(p.quantize_value(x)) as f64;
                if (x as f64 - xhat).abs() > bound {
                    return Err(format!(
                        "bits={bits}: |{x} - {xhat}| > Delta/2 = {bound}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bitpack_roundtrip_arbitrary_lengths() {
    check(
        Config { cases: 128, seed: 0xB17 },
        |rng| {
            let bits = 1 + rng.below(8) as u8;
            let len = rng.below(200);
            let codes: Vec<u32> =
                (0..len).map(|_| rng.next_u64() as u32 & ((1u32 << bits) - 1)).collect();
            (bits, codes)
        },
        |(bits, codes)| {
            let packed = BitPacked::pack(codes, *bits).map_err(|e| e.to_string())?;
            if packed.unpack() != *codes {
                return Err("unpack != original".into());
            }
            // Byte round-trip too.
            let bytes = packed.to_bytes();
            let (back, used) = BitPacked::from_bytes(&bytes).map_err(|e| e.to_string())?;
            if used != bytes.len() || back.unpack() != *codes {
                return Err("byte round-trip failed".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_group_quantize_matches_per_group_affine() {
    check(
        Config { cases: 64, seed: 0x64 },
        |rng| {
            let group = [4usize, 8, 16][rng.below(3)];
            let groups = 1 + rng.below(6);
            let bits = 2 + rng.below(7) as u8;
            let mut v = vec![0.0f32; group * groups];
            rng.fill_normal(&mut v, 0.05);
            (bits, group, v)
        },
        |(bits, group, v)| {
            let gq = GroupQuantized::quantize(v, *bits, *group).map_err(|e| e.to_string())?;
            let dq = gq.dequantize();
            for (chunk_i, chunk) in v.chunks_exact(*group).enumerate() {
                let p = AffineParams::from_slice(chunk, *bits).map_err(|e| e.to_string())?;
                for (j, &x) in chunk.iter().enumerate() {
                    let want = p.dequantize_code(p.quantize_value(x));
                    let got = dq[chunk_i * group + j];
                    if (want - got).abs() > 1e-6 {
                        return Err(format!("group {chunk_i}[{j}]: {got} != {want}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fused_flat_merge_matches_naive() {
    check(
        Config { cases: 48, seed: 0xF0 },
        |rng| {
            let group = 8usize;
            let n = group * (1 + rng.below(8));
            let t = 1 + rng.below(4);
            let bits = 2 + rng.below(7) as u8;
            let mut pre = vec![0.0f32; n];
            rng.fill_normal(&mut pre, 0.3);
            let taus: Vec<Vec<f32>> = (0..t)
                .map(|_| {
                    let mut v = vec![0.0f32; n];
                    rng.fill_normal(&mut v, 0.02);
                    v
                })
                .collect();
            let lams: Vec<f32> = (0..t).map(|_| rng.uniform(0.0, 1.0)).collect();
            (bits, group, pre, taus, lams)
        },
        |(bits, group, pre, taus, lams)| {
            let gqs: Vec<GroupQuantized> = taus
                .iter()
                .map(|v| GroupQuantized::quantize(v, *bits, *group).unwrap())
                .collect();
            let refs: Vec<&GroupQuantized> = gqs.iter().collect();
            let mut fused_out = Vec::new();
            fused::dequant_merge_flat(pre, &refs, lams, &mut fused_out)
                .map_err(|e| e.to_string())?;
            // Naive: dequantize each, accumulate.
            let mut naive = pre.clone();
            for (gq, lam) in gqs.iter().zip(lams) {
                for (d, v) in naive.iter_mut().zip(gq.dequantize()) {
                    *d += lam * v;
                }
            }
            for (i, (a, b)) in fused_out.iter().zip(&naive).enumerate() {
                if (a - b).abs() > 1e-4 {
                    return Err(format!("[{i}] fused {a} != naive {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tvq_checkpoint_error_within_eq3_budget() {
    // Per-tensor: ||tau - tau_hat||_inf <= Delta/2 with Delta from the
    // tensor's own range — the Eq. 3 bound lifted to checkpoints.
    check(
        Config { cases: 48, seed: 0x7C },
        |rng| {
            let bits = 2 + rng.below(7) as u8;
            let std = rng.uniform(0.001, 0.2);
            let mut fork = rng.fork(9);
            (bits, rand_ck(&mut fork, std))
        },
        |(bits, ck)| {
            let q = QuantizedCheckpoint::quantize(ck, *bits).map_err(|e| e.to_string())?;
            let dq = q.dequantize().map_err(|e| e.to_string())?;
            for (name, t) in ck.iter() {
                let (lo, hi) = {
                    let mut lo = f32::INFINITY;
                    let mut hi = f32::NEG_INFINITY;
                    for &v in t.data() {
                        lo = lo.min(v);
                        hi = hi.max(v);
                    }
                    (lo, hi)
                };
                let delta = (hi - lo) / ((1u32 << *bits) - 1) as f32;
                let bound = delta / 2.0 + 1e-6;
                let back = dq.get(name).map_err(|e| e.to_string())?;
                for (a, b) in t.data().iter().zip(back.data()) {
                    if (a - b).abs() > bound {
                        return Err(format!("{name}: |{a}-{b}| > {bound}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rtvq_reconstruction_identity_eq4() {
    // With error correction, tau_hat_t = dq(offset_t) + dq(base) must
    // approach tau_t as offset bits grow; at 8 bits the residual is tiny.
    check(
        Config { cases: 32, seed: 0x44 },
        |rng| {
            let mut fork = rng.fork(1);
            let pre = rand_ck(&mut fork, 0.3);
            let fts: Vec<Checkpoint> = (0..3)
                .map(|i| {
                    let mut ft = pre.clone();
                    let mut r = fork.fork(i as u64);
                    for (_, t) in ft.iter_mut() {
                        for v in t.data_mut() {
                            *v += r.normal_f32(0.02);
                        }
                    }
                    ft
                })
                .collect();
            (pre, fts)
        },
        |(pre, fts)| {
            let r = Rtvq::quantize(pre, fts, 8, 8, true, &ExecCtx::sequential())
                .map_err(|e| e.to_string())?;
            for (t, ft) in fts.iter().enumerate() {
                let tau = ft.sub(pre).unwrap();
                let tau_hat = r.dequantize_task(t).map_err(|e| e.to_string())?;
                let err = tau.l2_dist(&tau_hat).unwrap();
                let norm = tau.l2_dist(&tau.scale(0.0)).unwrap();
                if err > 0.02 * norm.max(1e-6) {
                    return Err(format!("task {t}: rel err {}", err / norm));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rtvq_beats_tvq_at_two_bits_eq5() {
    // Eq. 5 on random zoos whose offsets are much smaller than the shared
    // drift — the regime the decomposition is designed for.
    check(
        Config { cases: 24, seed: 0x55 },
        |rng| {
            let mut fork = rng.fork(3);
            let pre = rand_ck(&mut fork, 0.3);
            // Shared drift + small per-task offsets.
            let mut drift = pre.scale(0.0);
            for (_, t) in drift.iter_mut() {
                for v in t.data_mut() {
                    *v = fork.normal_f32(0.05);
                }
            }
            let fts: Vec<Checkpoint> = (0..4)
                .map(|i| {
                    let mut ft = pre.add(&drift).unwrap();
                    let mut r = fork.fork(100 + i as u64);
                    for (_, t) in ft.iter_mut() {
                        for v in t.data_mut() {
                            *v += r.normal_f32(0.01);
                        }
                    }
                    ft
                })
                .collect();
            (pre, fts)
        },
        |(pre, fts)| {
            let mut tvq2 = 0.0;
            for ft in fts {
                let tau = ft.sub(pre).unwrap();
                let q = QuantizedCheckpoint::quantize(&tau, 2).unwrap();
                tvq2 += q.quant_error(&tau).unwrap();
            }
            let r = Rtvq::quantize(pre, fts, 3, 2, true, &ExecCtx::sequential())
                .map_err(|e| e.to_string())?;
            let rtvq = r.total_quant_error(pre, fts).unwrap();
            if rtvq >= tvq2 {
                return Err(format!("RTVQ {rtvq} >= TVQ2 {tvq2}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_task_arithmetic_single_task_identity() {
    // TA with one task: merged = pre + lambda * tau, exactly.
    check(
        Config { cases: 32, seed: 0x1A },
        |rng| {
            let mut fork = rng.fork(5);
            let pre = rand_ck(&mut fork, 0.3);
            let tau = rand_ck(&mut fork, 0.02);
            let lam = fork.uniform(0.1, 1.0);
            (pre, tau, lam)
        },
        |(pre, tau, lam)| {
            let merged = TaskArithmetic::new(*lam)
                .merge(pre, std::slice::from_ref(tau))
                .map_err(|e| e.to_string())?;
            let MergedModel::Shared(m) = merged else {
                return Err("TA must be shared".into());
            };
            let mut want = pre.clone();
            want.axpy(*lam, tau).unwrap();
            for (name, t) in want.iter() {
                let got = m.get(name).unwrap();
                for (a, b) in t.data().iter().zip(got.data()) {
                    if (a - b).abs() > 1e-5 {
                        return Err(format!("{name}: {a} != {b}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_emr_single_task_reconstructs_finetuned_model() {
    // With one task, EMR's mask keeps every nonzero coordinate with the
    // elected sign and the rescale is 1 ⇒ model == pre + tau.
    check(
        Config { cases: 32, seed: 0xE1 },
        |rng| {
            let mut fork = rng.fork(7);
            let pre = rand_ck(&mut fork, 0.3);
            let tau = rand_ck(&mut fork, 0.02);
            (pre, tau)
        },
        |(pre, tau)| {
            let emr = EmrMerging;
            let arts = emr.artifacts(std::slice::from_ref(tau)).map_err(|e| e.to_string())?;
            let model = emr.model_for_task(pre, &arts, 0).map_err(|e| e.to_string())?;
            let mut want = pre.clone();
            want.axpy(1.0, tau).unwrap();
            for (name, t) in want.iter() {
                let got = model.get(name).unwrap();
                for (a, b) in t.data().iter().zip(got.data()) {
                    if (a - b).abs() > 1e-4 {
                        return Err(format!("{name}: {a} != {b}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_individual_returns_per_task_models() {
    check(
        Config { cases: 16, seed: 0x1D },
        |rng| {
            let mut fork = rng.fork(11);
            let pre = rand_ck(&mut fork, 0.3);
            let taus: Vec<Checkpoint> =
                (0..3).map(|_| rand_ck(&mut fork, 0.02)).collect();
            (pre, taus)
        },
        |(pre, taus)| {
            let merged = Individual::default().merge(pre, taus).map_err(|e| e.to_string())?;
            if merged.n_variants() != taus.len() {
                return Err("wrong variant count".into());
            }
            for (t, tau) in taus.iter().enumerate() {
                let mut want = pre.clone();
                want.axpy(1.0, tau).unwrap();
                if merged.for_task(t) != &want {
                    return Err(format!("task {t} model mismatch"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_checkpoint_flatten_roundtrip() {
    check(
        Config { cases: 48, seed: 0xF1 },
        |rng| {
            let mut fork = rng.fork(13);
            let block = [1usize, 8, 64][fork.below(3)];
            (rand_ck(&mut fork, 0.5), block)
        },
        |(ck, block)| {
            let flat = ck.flatten_padded(*block);
            if flat.len() % block != 0 || flat.len() < ck.numel() {
                return Err("bad padding".into());
            }
            let back = ck.unflatten_like(&flat).map_err(|e| e.to_string())?;
            if &back != ck {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quant_scheme_parse_label_roundtrip() {
    // Every scheme's label() must parse back to the same scheme —
    // registries persist labels, so this is a wire-format invariant.
    check(
        Config { cases: 200, seed: 0x5CE3 },
        |rng| {
            let bb = 1 + rng.below(8) as u8;
            let bo = 1 + rng.below(8) as u8;
            match rng.below(4) {
                0 => QuantScheme::Fp32,
                1 => QuantScheme::Fq(bb),
                2 => QuantScheme::Tvq(bb),
                _ => QuantScheme::Rtvq(bb, bo),
            }
        },
        |scheme| {
            let label = scheme.label();
            let back = QuantScheme::parse(&label)
                .map_err(|e| format!("label {label:?} failed to parse: {e}"))?;
            if back != *scheme {
                return Err(format!("{label:?} parsed to {back:?}, not {scheme:?}"));
            }
            // Lower-cased CLI spelling must agree too.
            let cli = label.to_ascii_lowercase();
            if QuantScheme::parse(&cli).map_err(|e| e.to_string())? != *scheme {
                return Err(format!("lowercase {cli:?} diverged"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quant_scheme_parse_rejects_out_of_range() {
    // Out-of-range widths must fail for every spelling family, including
    // the paper's b<base>o<offset> shorthand.
    for bad in [
        "tvq0", "tvq9", "tvq16", "fq0", "fq9", "rtvq0o2", "rtvq3o0", "rtvq9o2",
        "rtvq3o9", "b0o2", "b3o9", "tvq-int0", "tvq-int9", "rtvq-b9o2",
    ] {
        assert!(QuantScheme::parse(bad).is_err(), "{bad:?} should be rejected");
    }
    // And the paper's legal shorthand still parses.
    assert_eq!(QuantScheme::parse("b3o2").unwrap(), QuantScheme::Rtvq(3, 2));
}

#[test]
fn prop_registry_payload_bitpack_roundtrip() {
    // Drive BitPacked through the QTVC v2 serialization path: random
    // checkpoints at every width 1..=8 with adversarial tensor lengths
    // (word-straddling 3/5/6/7-bit widths included), encoded to section
    // bytes and decoded back — must be bit-exact, and the code stream
    // must be byte-exact (no u64 padding on the wire).
    check(
        Config { cases: 96, seed: 0x9E61 },
        |rng| {
            let bits = 1 + rng.below(8) as u8;
            // Lengths around word/byte boundaries for straddling widths.
            let lens = [1usize, 3, 7, 8, 9, 21, 63, 64, 65, 85, 127, 129];
            let n_tensors = 1 + rng.below(3);
            let mut ck = Checkpoint::new();
            for i in 0..n_tensors {
                let len = lens[rng.below(lens.len())];
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 0.05);
                ck.insert(&format!("t{i}"), Tensor::from_vec(v));
            }
            (bits, ck)
        },
        |(bits, ck)| {
            let q = QuantizedCheckpoint::quantize(ck, *bits).map_err(|e| e.to_string())?;
            let wire = encode_checkpoint_payload(&q);
            let back = decode_checkpoint_payload(&wire).map_err(|e| e.to_string())?;
            if back != q {
                return Err(format!("payload round-trip mismatch at {bits} bits"));
            }
            // The wire form must carry exactly ceil(numel*bits/8) code
            // bytes per tensor (plus metadata), never word-padded.
            for (name, qt) in q.iter() {
                let exact = (qt.numel() * *bits as usize).div_ceil(8);
                if qt.codes.packed_bytes().len() != exact {
                    return Err(format!("{name}: code bytes not exact"));
                }
            }
            Ok(())
        },
    );
}
