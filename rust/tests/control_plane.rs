//! Control-plane suite (ISSUE 6 acceptance): zero-downtime hot-swap and
//! graceful drain.
//!
//! * Publishing generation G+1 while readers hammer the variant never
//!   fails an in-flight G request: every concurrent result is bit-exact
//!   against the G *or* G+1 baseline (no torn reads), every post-publish
//!   request serves G+1 exactly, and the superseded mapping unmaps only
//!   after its last reader drops (refcount-zero unmap).
//! * Decodes through the control plane are bit-identical at every thread
//!   count — the PR-5 determinism contract extends through the swap.
//! * A `Draining` variant completes already-admitted work, then rejects
//!   new admissions with a typed error; an expired drain deadline
//!   flushes the still-queued remainder with
//!   [`ControlError::DrainDeadlineExpired`].
//!
//! `TVQ_SMOKE=1` shrinks the reader load, not the assertions.

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

mod common;

use common::fixtures::{smoke, THREADS};
use tvq::checkpoint::Checkpoint;
use tvq::coordinator::control::{ControlError, ControlPlane, VariantConfig, VariantState};
use tvq::coordinator::ModelCache;
use tvq::util::exec::ExecCtx;
use tvq::util::pool::Pool;

const N_TASKS: usize = 3;

fn tmpdir(tag: &str) -> PathBuf {
    common::fixtures::tmpdir("ctl", tag)
}

/// Pack a synthetic zoo at `dir/name` and return (path, per-task decoded
/// baselines).  Baselines are decoded sequentially from a throwaway
/// open, so they are independent of anything the control plane does.
fn pack(dir: &Path, name: &str, seed: u64) -> (PathBuf, Vec<Checkpoint>) {
    common::fixtures::pack_tvq4(dir, name, N_TASKS, seed)
}

/// Submit task `t` decoding through an explicit pool width and block for
/// the result (the PR-5 contract: width never changes bits).
fn decode_with_width(
    variant: &tvq::coordinator::Variant,
    t: usize,
    threads: usize,
) -> Checkpoint {
    let rx = variant
        .submit(move |generation| {
            generation
                .registry()
                .load_task_vector(t, &ExecCtx::with_pool(&Pool::new(threads)))
                .map_err(|e| ControlError::JobFailed { error: format!("{e:#}") })
        })
        .unwrap();
    rx.recv().unwrap().unwrap()
}

#[test]
fn hot_swap_under_load_is_bit_exact_and_unmaps_on_last_pin() {
    let dir = tmpdir("swap");
    let (path, base_a) = pack(&dir, "zoo.qtvc", 11);
    // Stage generation 2 directly at the publish path (`<path>.next`);
    // its baselines are decoded before the swap and outlive the rename.
    let (_staged, base_b) = pack(&dir, "zoo.qtvc.next", 22);

    let plane = ControlPlane::new(Arc::new(ModelCache::new()));
    let cfg = VariantConfig { queue_cap: 4096, ..VariantConfig::default() };
    let variant = plane.load_variant("zoo", &path, &cfg).unwrap();

    // Pre-swap: generation 1 decodes bit-exactly at every pool width.
    for &threads in &THREADS {
        for t in 0..N_TASKS {
            assert_eq!(
                decode_with_width(&variant, t, threads),
                base_a[t],
                "gen 1 decode diverged at {threads} threads, task {t}"
            );
        }
    }

    // Readers hammer the variant while the main thread publishes G+1.
    let n_readers = if smoke() { 2 } else { 4 };
    let iters = if smoke() { 8 } else { 40 };
    let readers: Vec<_> = (0..n_readers)
        .map(|r| {
            let variant = variant.clone();
            std::thread::spawn(move || {
                let mut out: Vec<(usize, Checkpoint)> = Vec::with_capacity(iters);
                for i in 0..iters {
                    let t = (r + i) % N_TASKS;
                    let rx = variant.submit_task_vector(t).unwrap();
                    out.push((t, rx.recv().unwrap().unwrap()));
                }
                out
            })
        })
        .collect();

    // Let the readers get in flight, then swap under them.
    std::thread::sleep(Duration::from_millis(10));
    let generation = plane.publish_staged("zoo").unwrap();
    assert_eq!(generation, 2);
    assert_eq!(variant.registry().generation(), 2);
    assert_eq!(variant.metrics().generation.load(std::sync::atomic::Ordering::Relaxed), 2);

    // Every concurrent result is bit-exact against one generation's
    // baseline — a torn read would match neither.
    for handle in readers {
        for (t, got) in handle.join().unwrap() {
            assert!(
                got == base_a[t] || got == base_b[t],
                "concurrent decode of task {t} matches neither generation bit-exactly"
            );
        }
    }

    // Post-publish, every request serves generation 2 — at every width.
    for &threads in &THREADS {
        for t in 0..N_TASKS {
            assert_eq!(
                decode_with_width(&variant, t, threads),
                base_b[t],
                "gen 2 decode diverged at {threads} threads, task {t}"
            );
        }
    }

    // With the last generation-1 pin dropped (all jobs completed above),
    // the old mapping is gone: only generation 2 stays live.  Poll
    // briefly — the worker drops the final pin just after replying.
    let t0 = Instant::now();
    while variant.registry().live_generations() != vec![2] {
        assert!(t0.elapsed() < Duration::from_secs(10), "generation 1 never unmapped");
        std::thread::sleep(Duration::from_millis(2));
    }

    drop(variant);
    plane.drain_variant("zoo", Some(Duration::from_secs(10))).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn drain_completes_admitted_work_then_rejects_new_admissions() {
    let dir = tmpdir("drain-clean");
    let (path, baselines) = pack(&dir, "zoo.qtvc", 5);
    let plane = ControlPlane::new(Arc::new(ModelCache::new()));
    let variant = plane.load_variant("zoo", &path, &VariantConfig::default()).unwrap();

    // Queue a burst, then drain with a generous deadline: everything
    // already admitted completes (bit-exactly), nothing is flushed.
    let n_jobs = if smoke() { 4 } else { 16 };
    let receivers: Vec<_> =
        (0..n_jobs).map(|i| variant.submit_task_vector(i % N_TASKS).unwrap()).collect();
    plane.drain_variant("zoo", Some(Duration::from_secs(30))).unwrap();
    assert!(matches!(variant.state(), VariantState::Draining | VariantState::Terminated));

    // New admissions are rejected with the typed error immediately.
    let err = variant.submit_task_vector(0).unwrap_err();
    assert!(
        matches!(err, ControlError::VariantUnavailable { .. }),
        "draining variant accepted new work: {err}"
    );

    for (i, rx) in receivers.into_iter().enumerate() {
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(got, baselines[i % N_TASKS], "queued job {i} corrupted by drain");
    }
    assert!(variant.await_state(&VariantState::Terminated, Duration::from_secs(10)));

    let m = variant.metrics().snapshot();
    assert_eq!(m.completed, n_jobs as u64);
    assert_eq!(m.drained, 0, "a clean drain flushed jobs it had time to run");
    assert_eq!(m.queue_depth, 0);

    // A terminated variant can be removed; the slot disappears.
    plane.remove_variant("zoo").unwrap();
    assert!(plane.get("zoo").is_none());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn drain_deadline_expiry_flushes_queue_with_typed_errors() {
    let dir = tmpdir("drain-expire");
    let (path, _) = pack(&dir, "zoo.qtvc", 9);
    let plane = ControlPlane::new(Arc::new(ModelCache::new()));
    let variant = plane.load_variant("zoo", &path, &VariantConfig::default()).unwrap();

    // Job 1 parks the worker on a gate until the test releases it; the
    // `started` signal guarantees it is in flight (not merely queued)
    // before anything else happens.
    let (started_tx, started_rx) = mpsc::channel::<()>();
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let blocker = variant
        .submit(move |_generation| {
            started_tx.send(()).unwrap();
            gate_rx.recv().ok();
            Ok(())
        })
        .unwrap();
    started_rx.recv_timeout(Duration::from_secs(10)).unwrap();

    // Queue more work behind the parked job, then drain with a deadline
    // far shorter than the park.
    let n_queued = if smoke() { 3 } else { 8 };
    let queued: Vec<_> =
        (0..n_queued).map(|i| variant.submit_task_vector(i % N_TASKS).unwrap()).collect();
    plane.drain_variant("zoo", Some(Duration::from_millis(50))).unwrap();

    // Let the deadline lapse while the worker is still parked, then
    // release it.  The in-flight job completes normally; the queued
    // remainder is flushed with the typed error.
    std::thread::sleep(Duration::from_millis(120));
    gate_tx.send(()).unwrap();

    assert!(blocker.recv_timeout(Duration::from_secs(10)).unwrap().is_ok());
    for (i, rx) in queued.into_iter().enumerate() {
        let got = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        match got {
            Err(ControlError::DrainDeadlineExpired { ref variant }) => {
                assert_eq!(variant, "zoo");
            }
            other => panic!("queued job {i} was not flushed with the typed error: {other:?}"),
        }
    }
    assert!(variant.await_state(&VariantState::Terminated, Duration::from_secs(10)));

    let m = variant.metrics().snapshot();
    assert_eq!(m.completed, 1, "only the parked job had time to run");
    assert_eq!(m.drained, n_queued as u64);
    assert_eq!(m.queue_depth, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn swap_artifacts_are_refused_by_registry_open_guard() {
    // `is_swap_artifact` is what `tvq registry verify` consults before
    // opening; pin the contract here where the CLI behavior is specified.
    use tvq::coordinator::control::is_swap_artifact;
    assert!(is_swap_artifact(Path::new("/srv/zoo.qtvc.next")));
    assert!(is_swap_artifact(Path::new("/srv/zoo.tmp")));
    assert!(!is_swap_artifact(Path::new("/srv/zoo.qtvc")));
}
