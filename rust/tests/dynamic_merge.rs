//! Dynamic-merging suite (ISSUE 8 acceptance): the per-request routed
//! serving path — router → [`MergeSpec`] → `ModelCache` delta patch —
//! must be a pure latency optimization, never a numerics change.
//!
//! * The canonical routed merge ([`merge_spec`]) is
//!   bit-identical across thread counts 1/2/8 and across `Mmap`/`Pread`
//!   section reads, over a **kind-5 binary-switch** (v5) registry — the
//!   newest wire format serves through the routed path from day one.
//! * A one-task delta patch (`cached + lambda_t * tau_t`) is
//!   bit-identical to the full re-merge it replaces, along growing
//!   chains and A -> B -> A revisits (byte-identical on return),
//!   verified against a cold cache that full-merges every spec.
//! * Requests are classified as patches vs full builds exactly as the
//!   cache documents (observed through `Metrics`), and the router is
//!   deterministic: permuted argument orders land on the same variant.

mod common;

use std::sync::Arc;

use common::fixtures::{bits_equal, onebit_cfg, pack_planned, THREADS};
use tvq::coordinator::router::merge_spec;
use tvq::coordinator::{Metrics, ModelCache, Router};
use tvq::merge::MergedModel;
use tvq::registry::{IoMode, OpenOptions, PackedRegistrySource, Registry, TaskVectorSource};
use tvq::util::exec::ExecCtx;
use tvq::util::pool::Pool;

const N_TASKS: usize = 4;

fn tmp(name: &str) -> std::path::PathBuf {
    common::fixtures::tmp("dynmerge", name)
}

/// Distinct, sign-mixed lambdas — no two tasks share a coefficient, so
/// an accidentally swapped accumulation order cannot cancel out.
const LAMS: [f32; 4] = [0.4, -0.15, 0.3, 0.2];

fn spec_for(router: &Router, tasks: &[usize]) -> tvq::coordinator::MergeSpec {
    let lams: Vec<f32> = tasks.iter().map(|&t| LAMS[t]).collect();
    router.route(tasks, &lams).unwrap()
}

#[test]
fn routed_merge_is_bit_exact_across_threads_and_io_modes() {
    let dir = tmp("canonical");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let (path, pre, _fts, plan) =
        pack_planned(&dir, "zoo.qtvc", N_TASKS, 0xD1A0, &onebit_cfg(256));
    assert!(plan.has_onebit_arms(), "suite must serve kind-5 sections");
    let router = Router::new(N_TASKS);
    let specs = [
        spec_for(&router, &[2]),
        spec_for(&router, &[0, 2]),
        spec_for(&router, &[0, 1, 2, 3]),
    ];

    // Sequential Mmap is the reference for every (mode, threads) cell.
    let reference = PackedRegistrySource::open(&path).unwrap();
    assert_eq!(reference.registry().version(), 5, "onebit-only plan must write v5");
    let seq = Pool::sequential();
    for spec in &specs {
        let want = match merge_spec(spec, &pre, &reference, &ExecCtx::with_pool(&seq)).unwrap() {
            MergedModel::Shared(ck) => ck,
            other => panic!("routed merges are shared, got {} variants", other.n_variants()),
        };
        for mode in [IoMode::Mmap, IoMode::Pread] {
            let source = PackedRegistrySource::from_registry(
                Registry::open_with(&path, OpenOptions::new().io(mode)).unwrap(),
            );
            for threads in THREADS {
                let ctx = ExecCtx::with_pool(&Pool::new(threads));
                let got = merge_spec(spec, &pre, &source, &ctx).unwrap();
                assert!(
                    bits_equal(got.for_task(0), &want),
                    "routed merge of {:?} diverged at {mode:?} threads={threads}",
                    spec.tasks()
                );
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn delta_patch_chain_is_bit_identical_to_full_remerge() {
    let dir = tmp("chain");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let (path, pre, _fts, _plan) =
        pack_planned(&dir, "zoo.qtvc", N_TASKS, 0xD1A1, &onebit_cfg(256));
    let source = PackedRegistrySource::open(&path).unwrap();
    let router = Router::new(N_TASKS);

    // Growing chain: {0} -> {0,1} -> {0,1,2} -> {0,1,2,3}.  The warm
    // cache full-builds once, then patches each extension; a cold cache
    // full-merges every spec.  Bytes must agree at every link.
    let chain: Vec<_> =
        (1..=N_TASKS).map(|k| spec_for(&router, &(0..k).collect::<Vec<_>>())).collect();
    let warm = ModelCache::new();
    let metrics = Arc::new(Metrics::new());
    warm.set_metrics(metrics.clone());
    let mut served = Vec::new();
    for spec in &chain {
        served.push(warm.get_or_merge_routed(spec, &pre, &source).unwrap());
    }
    let s = metrics.snapshot();
    assert_eq!(s.merge_builds, 1, "only the chain root is a full build");
    assert_eq!(s.delta_patches, (N_TASKS - 1) as u64, "each extension must patch");

    for (spec, patched) in chain.iter().zip(&served) {
        let cold = ModelCache::new();
        let full = cold.get_or_merge_routed(spec, &pre, &source).unwrap();
        assert!(
            bits_equal(patched.for_task(0), full.for_task(0)),
            "patched {:?} diverged from cold full re-merge",
            spec.tasks()
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn a_b_a_revisits_serve_byte_identical_floats() {
    let dir = tmp("aba");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let (path, pre, _fts, _plan) =
        pack_planned(&dir, "zoo.qtvc", N_TASKS, 0xD1A2, &onebit_cfg(256));
    let source = PackedRegistrySource::open(&path).unwrap();
    let router = Router::new(N_TASKS);
    let a = spec_for(&router, &[0, 1]);
    let b = spec_for(&router, &[0, 1, 2]);

    let cache = ModelCache::new();
    let metrics = Arc::new(Metrics::new());
    cache.set_metrics(metrics.clone());
    let first_a = cache.get_or_merge_routed(&a, &pre, &source).unwrap();
    let first_b = cache.get_or_merge_routed(&b, &pre, &source).unwrap();
    // Revisit A: a plain hit — the same bytes, with nothing recorded.
    let again_a = cache.get_or_merge_routed(&a, &pre, &source).unwrap();
    assert!(bits_equal(again_a.for_task(0), first_a.for_task(0)), "A -> B -> A revisit");
    let s = metrics.snapshot();
    assert_eq!((s.merge_builds, s.delta_patches), (1, 1), "revisit must not rebuild");

    // Evict A and request it again: the rebuild (a fresh full merge —
    // A is B's *parent*, so B is never its patch base) must reproduce
    // the original bytes exactly.
    let (method, scheme) = a.variant_key(&source.source_id());
    assert!(cache.evict(&method, &scheme), "A was cached");
    let rebuilt_a = cache.get_or_merge_routed(&a, &pre, &source).unwrap();
    assert!(bits_equal(rebuilt_a.for_task(0), first_a.for_task(0)), "A rebuild after evict");
    assert_eq!(metrics.snapshot().merge_builds, 2, "rebuild is a full build");

    // And B, still cached, is untouched by A's eviction.
    let again_b = cache.get_or_merge_routed(&b, &pre, &source).unwrap();
    assert!(bits_equal(again_b.for_task(0), first_b.for_task(0)));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn router_permutations_land_on_the_same_cached_variant() {
    let dir = tmp("router");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let (path, pre, _fts, _plan) =
        pack_planned(&dir, "zoo.qtvc", N_TASKS, 0xD1A3, &onebit_cfg(256));
    let source = PackedRegistrySource::open(&path).unwrap();
    let router = Router::new(N_TASKS);

    let cache = ModelCache::new();
    let metrics = Arc::new(Metrics::new());
    cache.set_metrics(metrics.clone());
    let orders: [&[usize]; 3] = [&[0, 2, 3], &[3, 0, 2], &[2, 3, 0]];
    let mut served = Vec::new();
    for tasks in orders {
        let lams: Vec<f32> = tasks.iter().map(|&t| LAMS[t]).collect();
        let spec = router.route(tasks, &lams).unwrap();
        served.push(cache.get_or_merge_routed(&spec, &pre, &source).unwrap());
    }
    // One variant, built once; every permutation serves the same Arc.
    assert_eq!(cache.len(), 1, "permutations must not mint new variants");
    assert_eq!(metrics.snapshot().merge_builds, 1);
    assert!(Arc::ptr_eq(&served[0], &served[1]) && Arc::ptr_eq(&served[0], &served[2]));

    // Out-of-range and malformed requests never reach the cache.
    assert!(router.route(&[N_TASKS], &[0.1]).is_err());
    assert!(router.route(&[0, 0], &[0.1, 0.2]).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn disjoint_subsets_full_build_and_lambda_prefix_mismatch_never_patches() {
    let dir = tmp("classify");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let (path, pre, _fts, _plan) =
        pack_planned(&dir, "zoo.qtvc", N_TASKS, 0xD1A4, &onebit_cfg(256));
    let source = PackedRegistrySource::open(&path).unwrap();
    let router = Router::new(N_TASKS);

    let cache = ModelCache::new();
    let metrics = Arc::new(Metrics::new());
    cache.set_metrics(metrics.clone());
    // Disjoint subsets share no patch ancestor: both full-build.
    cache.get_or_merge_routed(&spec_for(&router, &[0, 1]), &pre, &source).unwrap();
    cache.get_or_merge_routed(&spec_for(&router, &[2, 3]), &pre, &source).unwrap();
    // Same task prefix at a different lambda is a different parent key:
    // full build, never a patch off the wrong base.
    let shifted = router.route(&[0, 1, 2], &[LAMS[0], LAMS[1] + 0.05, LAMS[2]]).unwrap();
    cache.get_or_merge_routed(&shifted, &pre, &source).unwrap();
    let s = metrics.snapshot();
    assert_eq!(s.merge_builds, 3);
    assert_eq!(s.delta_patches, 0, "nothing here is a valid patch");

    // The shifted variant still matches its own canonical merge.
    let want = merge_spec(&shifted, &pre, &source, &ExecCtx::sequential()).unwrap();
    let got = cache.get_or_merge_routed(&shifted, &pre, &source).unwrap();
    assert!(bits_equal(got.for_task(0), want.for_task(0)));
    std::fs::remove_dir_all(&dir).ok();
}
