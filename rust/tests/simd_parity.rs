//! SIMD kernel parity (ISSUE 10): every kernel [`detected`] on the
//! running machine must produce **bit-identical** f32 output to the
//! scalar reference for all four dispatched primitives — low-bit
//! unpack, group dequant/axpy, sparse scatter-axpy, 1-bit signed axpy —
//! across every width 1..=8, unaligned range starts, group-boundary
//! straddles, and NaN / denormal / signed-zero scales.  Together with
//! `pool_determinism.rs` this pins the extended contract: merged floats
//! are identical at *any thread count × any kernel*, with `threads=1 ×
//! scalar` the reference.
//!
//! The suite doubles as the producer of the cross-runtime parity
//! fixture: `export_python_parity_fixtures` writes Rust-packed section
//! bytes plus scalar-decoded goldens under `target/parity/`, which
//! `python/tests/test_packed_merge_parity.py` decodes through the
//! Pallas `packed_merge` kernels and compares byte-for-byte.
//!
//! [`detected`]: tvq::quant::simd::detected

mod common;

use common::fixtures::{assert_ckpt_bit_eq, het_cfg, het_zoo, onebit_cfg, tmp, THREADS};
use tvq::planner::{fused_merge, probe, solve, write_planned_registry};
use tvq::quant::simd::{self, Kernel};
use tvq::quant::{
    BinarySwitch, BinarySwitchView, BitPacked, BitPackedView, GroupQuantized,
    GroupQuantizedView, SparseGroupQuantized, SparseGroupQuantizedView,
};
use tvq::registry::Registry;
use tvq::util::exec::ExecCtx;
use tvq::util::pool::Pool;
use tvq::util::rng::Rng;

/// Serialize a group payload's wire params (scales then zps, 4 LE bytes
/// per group each — the kind-2 section layout).
fn group_params(gq: &GroupQuantized) -> Vec<u8> {
    let mut out = Vec::with_capacity(gq.n_groups() * 8);
    for &s in &gq.scales {
        out.extend_from_slice(&s.to_le_bytes());
    }
    for &z in &gq.zps {
        out.extend_from_slice(&z.to_le_bytes());
    }
    out
}

fn f32_bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn rand_codes(rng: &mut Rng, len: usize, bits: u8) -> Vec<u32> {
    (0..len).map(|_| rng.below(1usize << bits) as u32).collect()
}

#[test]
fn unpack_range_parity_all_widths_starts_and_lengths() {
    let mut rng = Rng::new(0x51D0);
    let len = 1013; // not a multiple of any block size; ragged tails everywhere
    for bits in 1u8..=8 {
        let codes = rand_codes(&mut rng, len, bits);
        let packed = BitPacked::pack(&codes, bits).unwrap();
        let bytes = packed.packed_bytes();
        let view = BitPackedView::new(bits, len, &bytes).unwrap();
        for k in simd::detected() {
            for &start in &[0usize, 1, 3, 7, 8, 13, 64, 129] {
                for &n in &[0usize, 1, 5, 8, 16, 31, 257, len - start] {
                    if start + n > len {
                        continue;
                    }
                    let mut got = vec![u32::MAX; n];
                    view.unpack_range_into_k(k, start, &mut got);
                    assert_eq!(
                        got,
                        &codes[start..start + n],
                        "kernel {} bits {bits} range [{start}, +{n})",
                        k.label()
                    );
                }
            }
        }
    }
}

#[test]
fn unpack_blocks_decodes_an_exact_prefix() {
    // The dispatched block decoder may stop at any kernel-specific block
    // multiple; whatever prefix it claims must be exact.
    let mut rng = Rng::new(0x51D1);
    let len = 777;
    for bits in 1u8..=8 {
        let codes = rand_codes(&mut rng, len, bits);
        let packed = BitPacked::pack(&codes, bits).unwrap();
        let bytes = packed.packed_bytes();
        for k in simd::detected() {
            let mut out = vec![u32::MAX; len];
            let done = simd::unpack_blocks(k, bits, &bytes, &mut out);
            assert!(done <= len, "kernel {} bits {bits}: done {done} > {len}", k.label());
            assert_eq!(
                &out[..done],
                &codes[..done],
                "kernel {} bits {bits}: prefix of {done} codes diverged",
                k.label()
            );
        }
    }
}

#[test]
fn group_axpy_and_dequant_parity_across_shards() {
    let mut rng = Rng::new(0x51D2);
    // Group widths that straddle (96) and align with (128/256) the 4/8/16
    // lane blocks the kernels use.
    for &(len, group) in &[(1024usize, 128usize), (960, 96), (768, 256)] {
        let mut data = vec![0.0f32; len];
        rng.fill_normal(&mut data, 0.05);
        for &bits in &[2u8, 3, 4, 8] {
            let gq = GroupQuantized::quantize(&data, bits, group).unwrap();
            let params = group_params(&gq);
            let bytes = gq.codes.packed_bytes();
            let view = GroupQuantizedView::new(
                bits,
                group,
                gq.n_groups(),
                &params,
                BitPackedView::new(bits, len, &bytes).unwrap(),
            )
            .unwrap();
            let n_groups = gq.n_groups();
            let mut codes = Vec::new();

            // Scalar reference: one full-range pass of each op.
            let mut want_axpy = vec![0.25f32; len];
            view.axpy_groups_into_k(Kernel::Scalar, -0.75, 0, &mut want_axpy, &mut codes)
                .unwrap();
            let mut want_dq = vec![0.0f32; len];
            view.dequantize_groups_into_k(Kernel::Scalar, 0, &mut want_dq, &mut codes);

            for k in simd::detected() {
                // Full range and group-aligned shards of 1 / 3 groups.
                for &shard_groups in &[n_groups, 1, 3] {
                    let mut got_axpy = vec![0.25f32; len];
                    let mut got_dq = vec![0.0f32; len];
                    let mut g0 = 0;
                    while g0 < n_groups {
                        let g1 = (g0 + shard_groups).min(n_groups);
                        let (lo, hi) = (g0 * group, g1 * group);
                        view.axpy_groups_into_k(k, -0.75, g0, &mut got_axpy[lo..hi], &mut codes)
                            .unwrap();
                        view.dequantize_groups_into_k(k, g0, &mut got_dq[lo..hi], &mut codes);
                        g0 = g1;
                    }
                    assert_eq!(
                        f32_bits(&got_axpy),
                        f32_bits(&want_axpy),
                        "axpy: kernel {} bits {bits} group {group} shard {shard_groups}",
                        k.label()
                    );
                    assert_eq!(
                        f32_bits(&got_dq),
                        f32_bits(&want_dq),
                        "dequant: kernel {} bits {bits} group {group} shard {shard_groups}",
                        k.label()
                    );
                }
            }
        }
    }
}

#[test]
fn group_axpy_parity_with_nan_and_denormal_scales() {
    // Corrupt-adjacent params the wire can carry: NaN, denormal, zero and
    // negative scales / zero points.  Kernels must propagate them with
    // the exact bits the scalar loop produces.
    let mut rng = Rng::new(0x51D3);
    let (len, group, bits) = (64usize, 8usize, 4u8);
    let codes = rand_codes(&mut rng, len, bits);
    let packed = BitPacked::pack(&codes, bits).unwrap();
    let bytes = packed.packed_bytes();
    let scales = [f32::NAN, 1.0e-42, 0.0, -0.0, -3.5, f32::MIN_POSITIVE, 7.25, 1.5e-40];
    let zps = [0.0f32, 7.5, f32::NAN, 3.0, -2.0, 1.0e-41, 15.0, 0.5];
    let mut params = Vec::new();
    for s in scales {
        params.extend_from_slice(&s.to_le_bytes());
    }
    for z in zps {
        params.extend_from_slice(&z.to_le_bytes());
    }
    let view = GroupQuantizedView::new(
        bits,
        group,
        8,
        &params,
        BitPackedView::new(bits, len, &bytes).unwrap(),
    )
    .unwrap();
    let mut codes_scratch = Vec::new();
    let mut want = vec![0.5f32; len];
    view.axpy_groups_into_k(Kernel::Scalar, 0.375, 0, &mut want, &mut codes_scratch).unwrap();
    let mut want_dq = vec![0.0f32; len];
    view.dequantize_groups_into_k(Kernel::Scalar, 0, &mut want_dq, &mut codes_scratch);
    for k in simd::detected() {
        let mut got = vec![0.5f32; len];
        view.axpy_groups_into_k(k, 0.375, 0, &mut got, &mut codes_scratch).unwrap();
        assert_eq!(f32_bits(&got), f32_bits(&want), "axpy special scales: {}", k.label());
        let mut got_dq = vec![0.0f32; len];
        view.dequantize_groups_into_k(k, 0, &mut got_dq, &mut codes_scratch);
        assert_eq!(f32_bits(&got_dq), f32_bits(&want_dq), "dequant special: {}", k.label());
    }
}

#[test]
fn sparse_scatter_parity_with_mixed_mask_density() {
    let mut rng = Rng::new(0x51D4);
    let dense_len = 1000; // ends mid mask byte
    let mut data = vec![0.0f32; dense_len];
    rng.fill_normal(&mut data, 0.1);
    // Saturated head (0xFF bytes → the vector fast path), then stretches
    // of every-3rd and every-7th survivors (partial bytes), then a final
    // survivor inside the ragged tail byte.
    let mut keep: Vec<usize> = (0..128).collect();
    keep.extend((130..500).step_by(3));
    keep.extend((502..996).step_by(7));
    keep.push(999);
    let s = SparseGroupQuantized::quantize_indices(&data, &keep, 1.0, 4, 32).unwrap();
    let params = group_params(&s.survivors);
    let sbytes = s.survivors.codes.packed_bytes();
    let sview = GroupQuantizedView::new(
        4,
        32,
        s.survivors.n_groups(),
        &params,
        BitPackedView::new(4, s.survivors.len(), &sbytes).unwrap(),
    )
    .unwrap();
    let view =
        SparseGroupQuantizedView::new(dense_len, s.n_survivors, &s.mask, sview).unwrap();

    // Accumulator pre-filled with a mix of values including -0.0: the
    // scatter must leave every masked-out lane's bits untouched.
    let prefill: Vec<f32> = (0..dense_len)
        .map(|i| if i % 5 == 0 { -0.0 } else { (i as f32) * 0.125 - 40.0 })
        .collect();

    let (mut codes, mut vals) = (Vec::new(), Vec::new());
    let mut want = prefill.clone();
    view.axpy_range_into_k(Kernel::Scalar, -0.6, 0, &mut want, &mut codes, &mut vals);

    for k in simd::detected() {
        // Full range plus byte-aligned shards of 1 / 2 / 17 mask bytes.
        for &shard_bytes in &[125usize, 1, 2, 17] {
            let mut got = prefill.clone();
            let mut byte0 = 0;
            while byte0 * 8 < dense_len {
                let lo = byte0 * 8;
                let hi = (lo + shard_bytes * 8).min(dense_len);
                view.axpy_range_into_k(k, -0.6, byte0, &mut got[lo..hi], &mut codes, &mut vals);
                byte0 += shard_bytes;
            }
            assert_eq!(
                f32_bits(&got),
                f32_bits(&want),
                "sparse scatter: kernel {} shard_bytes {shard_bytes}",
                k.label()
            );
        }
    }
}

#[test]
fn binary_signed_parity_with_straddling_groups_and_special_scales() {
    let mut rng = Rng::new(0x51D5);
    // Group 67 never aligns with sign bytes: every vector call crosses a
    // group boundary mid-byte somewhere.
    let (len, group) = (1005usize, 67usize);
    let mut data = vec![0.0f32; len];
    rng.fill_normal(&mut data, 0.05);
    let b = BinarySwitch::quantize(&data, group).unwrap();
    // Replace a few scales with special values the wire could carry.
    let mut scales = b.scales.clone();
    scales[0] = f32::NAN;
    scales[3] = 1.0e-42;
    scales[7] = 0.0;
    scales[11] = -0.0;
    let mut params = Vec::new();
    for &s in &scales {
        params.extend_from_slice(&s.to_le_bytes());
    }
    let view = BinarySwitchView::new(group, b.n_groups(), &params, &b.signs).unwrap();

    let mut want = vec![0.25f32; len];
    view.axpy_range_into_k(Kernel::Scalar, -0.75, 0, &mut want);

    for k in simd::detected() {
        for &shard_bytes in &[126usize, 1, 3, 16] {
            let mut got = vec![0.25f32; len];
            let mut byte0 = 0;
            while byte0 * 8 < len {
                let lo = byte0 * 8;
                let hi = (lo + shard_bytes * 8).min(len);
                view.axpy_range_into_k(k, -0.75, byte0, &mut got[lo..hi]);
                byte0 += shard_bytes;
            }
            assert_eq!(
                f32_bits(&got),
                f32_bits(&want),
                "binary: kernel {} shard_bytes {shard_bytes}",
                k.label()
            );
        }
    }
}

#[test]
fn fused_merge_bit_identical_across_kernels_and_threads() {
    // End to end: planned registries covering every section family —
    // het_cfg plans dense kind-2 / residual / sparse kind-4 arms,
    // onebit_cfg forces every tensor onto kind-5 binary switches —
    // merged under every detected kernel at every pool width, must
    // reproduce the threads=1 × scalar reference exactly.
    let dir = tmp("simd_parity", "e2e");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let (pre, fts) = het_zoo(4, 0x51D6);
    let lams = [0.3f32, -0.2, 0.15, 0.4];

    for (tag, cfg) in [("het", het_cfg()), ("onebit", onebit_cfg(384))] {
        let profile = probe(&pre, &fts, &cfg).unwrap();
        let plan = solve(&profile, u64::MAX).unwrap();
        let path = dir.join(format!("planned_{tag}.qtvc"));
        write_planned_registry(&pre, &fts, &plan, &path).unwrap();
        let reg = Registry::open(&path).unwrap();

        let seq_scalar = ExecCtx::sequential().with_kernel(Kernel::Scalar);
        let reference = fused_merge(&reg, &pre, &lams, None, &seq_scalar).unwrap();
        let tau_ref = reg.load_task_vector(1, &seq_scalar).unwrap();

        for k in simd::detected() {
            for &t in &THREADS {
                let pool = Pool::new(t);
                let ctx = ExecCtx::with_pool(&pool).with_kernel(k);
                let merged = fused_merge(&reg, &pre, &lams, None, &ctx).unwrap();
                assert_ckpt_bit_eq(
                    &merged,
                    &reference,
                    &format!("fused_merge[{tag}] kernel={} threads={t}", k.label()),
                );
                let tau = reg.load_task_vector(1, &ctx).unwrap();
                assert_ckpt_bit_eq(
                    &tau,
                    &tau_ref,
                    &format!("load_task_vector[{tag}] kernel={} threads={t}", k.label()),
                );
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Write the cross-runtime parity fixture: Rust-packed kind-2 and
/// kind-4 payload bytes (codes as little-endian i32 words — the Pallas
/// `packed_merge` input convention), their wire params, and the
/// scalar-kernel decode as the f32 golden.
/// `python/tests/test_packed_merge_parity.py` loads these and asserts
/// the Python decode is byte-identical.  Output dir: `TVQ_PARITY_DIR`,
/// default `target/parity/` under the cargo workspace.
#[test]
fn export_python_parity_fixtures() {
    let dir = std::env::var("TVQ_PARITY_DIR").unwrap_or_else(|_| {
        format!("{}/target/parity", env!("CARGO_MANIFEST_DIR"))
    });
    let dir = std::path::PathBuf::from(dir);
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Rng::new(0x9A71);

    let f32s_to_le = |v: &[f32]| -> Vec<u8> {
        v.iter().flat_map(|x| x.to_le_bytes()).collect()
    };

    // kind-2: group-quantized dense payload, 4-bit, group 128.
    let (n2, group2, bits2) = (1024usize, 128usize, 4u8);
    let mut data = vec![0.0f32; n2];
    rng.fill_normal(&mut data, 0.05);
    let gq = GroupQuantized::quantize(&data, bits2, group2).unwrap();
    let gq_bytes = gq.codes.packed_bytes();
    let gq_view = BitPackedView::new(bits2, n2, &gq_bytes).unwrap();
    let words: Vec<u8> =
        gq.codes.to_i32_words().unwrap().iter().flat_map(|w| w.to_le_bytes()).collect();
    let codes_u8: Vec<u8> = gq.codes.iter().map(|c| c as u8).collect();
    let params2 = group_params(&gq);
    let view2 = GroupQuantizedView::new(bits2, group2, gq.n_groups(), &params2, gq_view).unwrap();
    let mut golden2 = vec![0.0f32; n2];
    let mut scratch = Vec::new();
    view2.dequantize_into_k(Kernel::Scalar, &mut golden2, &mut scratch);
    std::fs::write(dir.join("kind2_words.bin"), &words).unwrap();
    std::fs::write(dir.join("kind2_codes.bin"), &codes_u8).unwrap();
    std::fs::write(dir.join("kind2_scales.bin"), f32s_to_le(&gq.scales)).unwrap();
    std::fs::write(dir.join("kind2_zps.bin"), f32s_to_le(&gq.zps)).unwrap();
    std::fs::write(dir.join("kind2_golden.bin"), f32s_to_le(&golden2)).unwrap();

    // kind-4: sparse payload — bitmask + 4-bit group-quantized survivors
    // (group 32, so the padded survivor count stays i32-word aligned).
    let (n4, group4, bits4) = (512usize, 32usize, 4u8);
    let mut dense = vec![0.0f32; n4];
    rng.fill_normal(&mut dense, 0.1);
    let mut keep: Vec<usize> = (0..64).collect();
    keep.extend((66..n4).step_by(3));
    let s = SparseGroupQuantized::quantize_indices(&dense, &keep, 1.0, bits4, group4).unwrap();
    let s_bytes = s.survivors.codes.packed_bytes();
    let s_codes = BitPackedView::new(bits4, s.survivors.len(), &s_bytes).unwrap();
    let s_words: Vec<u8> =
        s.survivors.codes.to_i32_words().unwrap().iter().flat_map(|w| w.to_le_bytes()).collect();
    let params4 = group_params(&s.survivors);
    let sview = GroupQuantizedView::new(bits4, group4, s.survivors.n_groups(), &params4, s_codes)
        .unwrap();
    let view4 = SparseGroupQuantizedView::new(n4, s.n_survivors, &s.mask, sview).unwrap();
    let mut golden4 = vec![0.0f32; n4];
    let (mut codes, mut vals) = (Vec::new(), Vec::new());
    view4.dequantize_into_k(Kernel::Scalar, &mut golden4, &mut codes, &mut vals);
    std::fs::write(dir.join("kind4_mask.bin"), &s.mask).unwrap();
    std::fs::write(dir.join("kind4_words.bin"), &s_words).unwrap();
    std::fs::write(dir.join("kind4_scales.bin"), f32s_to_le(&s.survivors.scales)).unwrap();
    std::fs::write(dir.join("kind4_zps.bin"), f32s_to_le(&s.survivors.zps)).unwrap();
    std::fs::write(dir.join("kind4_golden.bin"), f32s_to_le(&golden4)).unwrap();

    let manifest = format!(
        concat!(
            "{{\n",
            "  \"kind2\": {{\"n\": {}, \"group\": {}, \"bits\": {}, \"n_groups\": {}}},\n",
            "  \"kind4\": {{\"dense_len\": {}, \"n_survivors\": {}, \"padded_survivors\": {}, ",
            "\"group\": {}, \"bits\": {}, \"n_groups\": {}}}\n",
            "}}\n"
        ),
        n2,
        group2,
        bits2,
        gq.n_groups(),
        n4,
        s.n_survivors,
        s.survivors.len(),
        group4,
        bits4,
        s.survivors.n_groups(),
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    eprintln!("[simd_parity] wrote python parity fixture to {}", dir.display());
}
