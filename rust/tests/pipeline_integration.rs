//! End-to-end pipeline integration: train a miniature zoo through PJRT,
//! quantize, merge, and evaluate — the whole paper loop at test scale.
//!
//! Uses a dedicated tiny TrainConfig (few steps) so the test finishes in
//! seconds; numeric claims are kept qualitative (fine-tuning helps, TVQ
//! error ≪ FQ error, RTVQ ≤ TVQ2) rather than matching table values.

use anyhow::Result;

use tvq::checkpoint::Checkpoint;
use tvq::data::classify::TaskSuite;
use tvq::data::VIT_S;
use tvq::exp::scheme_taus;
use tvq::merge::{standard_methods, Merger, TaskArithmetic};
use tvq::quant::{QuantScheme, QuantizedCheckpoint, Rtvq};
use tvq::runtime::Runtime;
use tvq::train::{self, TrainConfig};
use tvq::util::exec::ExecCtx;

const N_TASKS: usize = 3;

mod common;

/// One shared mini-zoo per test process (training is the expensive bit).
/// Returns `None` — and every test skips — when PJRT is unavailable
/// (offline builds use the vendored `xla` stub, which has no client).
fn mini_zoo() -> Option<&'static (Checkpoint, Vec<Checkpoint>, TaskSuite)> {
    use std::sync::OnceLock;
    static ZOO: OnceLock<Option<(Checkpoint, Vec<Checkpoint>, TaskSuite)>> = OnceLock::new();
    ZOO.get_or_init(|| {
        let rt = common::fixtures::runtime()?;
        let suite = TaskSuite::new(&VIT_S, N_TASKS, 4200);
        let cfg = TrainConfig { steps: 60, pool: 512, ..TrainConfig::default() };
        let (pre, _) =
            train::pretrain_classify(&rt, &VIT_S, &suite.pretrain_task(), &cfg, 0xA11)
                .expect("pretrain");
        let fts: Vec<Checkpoint> = suite
            .tasks
            .iter()
            .map(|t| {
                train::finetune_classify(&rt, &VIT_S, &pre, t, &cfg)
                    .expect("finetune")
                    .0
            })
            .collect();
        Some((pre, fts, suite))
    })
    .as_ref()
}

#[test]
fn finetuning_beats_pretrained_on_target_task() {
    let Some((pre, fts, suite)) = mini_zoo() else { return };
    let rt = Runtime::new().unwrap();
    for (t, task) in suite.tasks.iter().enumerate() {
        let acc_pre = tvq::eval::classify_accuracy(&rt, &VIT_S, pre, task).unwrap();
        let acc_ft = tvq::eval::classify_accuracy(&rt, &VIT_S, &fts[t], task).unwrap();
        assert!(
            acc_ft > acc_pre + 5.0,
            "task {t}: fine-tuned {acc_ft:.1}% should beat pre-trained {acc_pre:.1}%"
        );
    }
}

#[test]
fn task_vectors_have_narrow_range_observation() {
    // The Fig. 3 observation must hold on genuinely-trained checkpoints.
    let Some((pre, fts, _)) = mini_zoo() else { return };
    for ft in fts {
        let tau = ft.sub(pre).unwrap();
        let (flo, fhi) = ft.weight_range();
        let (tlo, thi) = tau.weight_range();
        let ratio = (fhi - flo) / (thi - tlo).max(1e-9);
        assert!(
            ratio > 3.0,
            "expected task-vector range well below checkpoint range, ratio={ratio}"
        );
    }
}

#[test]
fn tvq_error_below_fq_error_on_trained_zoo() {
    let Some((pre, fts, _)) = mini_zoo() else { return };
    let exact = scheme_taus(pre, fts, QuantScheme::Fp32).unwrap().taus;
    for bits in [2, 3, 4, 8] {
        let fq = scheme_taus(pre, fts, QuantScheme::Fq(bits)).unwrap().taus;
        let tvq = scheme_taus(pre, fts, QuantScheme::Tvq(bits)).unwrap().taus;
        let err = |a: &[Checkpoint]| -> f64 {
            exact.iter().zip(a).map(|(x, y)| x.l2_dist(y).unwrap()).sum()
        };
        assert!(
            err(&tvq) < err(&fq),
            "TVQ must beat FQ at {bits} bits: {} vs {}",
            err(&tvq),
            err(&fq)
        );
    }
}

#[test]
fn rtvq_error_below_tvq2_at_similar_budget() {
    // Eq. 5: the decomposition buys error reduction at ~equal bits.
    let Some((pre, fts, _)) = mini_zoo() else { return };
    let mut tvq2_err = 0.0;
    for ft in fts {
        let tau = ft.sub(pre).unwrap();
        let q = QuantizedCheckpoint::quantize(&tau, 2).unwrap();
        tvq2_err += q.quant_error(&tau).unwrap();
    }
    let r = Rtvq::quantize(pre, fts, 3, 2, true, &ExecCtx::sequential()).unwrap();
    let rtvq_err = r.total_quant_error(pre, fts).unwrap();
    assert!(
        rtvq_err < tvq2_err,
        "RTVQ-B3O2 ({rtvq_err}) must beat TVQ-INT2 ({tvq2_err})"
    );
}

#[test]
fn error_correction_reduces_rtvq_error() {
    let Some((pre, fts, _)) = mini_zoo() else { return };
    for (bb, bo) in [(2u8, 2u8), (3, 2), (4, 3)] {
        let with_ec = Rtvq::quantize(pre, fts, bb, bo, true, &ExecCtx::sequential())
            .unwrap()
            .total_quant_error(pre, fts)
            .unwrap();
        let without = Rtvq::quantize(pre, fts, bb, bo, false, &ExecCtx::sequential())
            .unwrap()
            .total_quant_error(pre, fts)
            .unwrap();
        assert!(
            with_ec <= without * 1.02,
            "EC should not hurt (B{bb}O{bo}): {with_ec} vs {without}"
        );
    }
}

#[test]
fn every_merge_method_runs_on_trained_vectors_and_beats_chance() {
    let Some((pre, fts, suite)) = mini_zoo() else { return };
    let rt = Runtime::new().unwrap();
    let taus = scheme_taus(pre, fts, QuantScheme::Tvq(3)).unwrap().taus;
    let chance = 100.0 / VIT_S.n_classes as f64;
    for method in standard_methods() {
        let merged = method.merge(pre, &taus).unwrap();
        let mut acc = 0.0;
        for (t, task) in suite.tasks.iter().enumerate() {
            acc +=
                tvq::eval::classify_accuracy(&rt, &VIT_S, merged.for_task(t), task).unwrap();
        }
        acc /= suite.tasks.len() as f64;
        assert!(
            acc > chance * 1.5,
            "{} @ TVQ3 should beat chance ({chance:.0}%): got {acc:.1}%",
            method.name()
        );
    }
}

#[test]
fn quantized_merge_tracks_fp32_merge() -> Result<()> {
    // The paper's headline: merging quantized task vectors performs like
    // merging full-precision ones.  At mini-zoo scale we allow a loose
    // band (10 accuracy points).
    let Some((pre, fts, suite)) = mini_zoo() else { return Ok(()) };
    let rt = Runtime::new()?;
    let ta = TaskArithmetic::default();
    let mut accs = Vec::new();
    for scheme in [QuantScheme::Fp32, QuantScheme::Tvq(4), QuantScheme::Rtvq(3, 2)] {
        let taus = scheme_taus(pre, fts, scheme)?.taus;
        let merged = ta.merge(pre, &taus)?;
        let mut acc = 0.0;
        for (t, task) in suite.tasks.iter().enumerate() {
            acc += tvq::eval::classify_accuracy(&rt, &VIT_S, merged.for_task(t), task)?;
        }
        accs.push(acc / suite.tasks.len() as f64);
    }
    let fp32 = accs[0];
    for (i, acc) in accs.iter().enumerate().skip(1) {
        assert!(
            (acc - fp32).abs() < 10.0,
            "scheme {i} diverges from FP32 merge: {acc:.1} vs {fp32:.1}"
        );
    }
    Ok(())
}
