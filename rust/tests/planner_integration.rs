//! Planner acceptance: the budget-aware pack planner must
//!
//! 1. fit a mixed-precision registry into the **measured** byte cost of
//!    a uniform RTVQ-B3O2 registry while reconstructing the task vectors
//!    with lower total error (the ISSUE-2 acceptance criterion),
//! 2. respect any feasible budget exactly (written file bytes == planned
//!    bytes <= budget) and degrade monotonically as budgets shrink —
//!    with the enlarged (sparse-arm) candidate set too,
//! 3. round-trip kind-2 `GroupQuantized` sections producer → registry →
//!    fused dequant-merge → served merged model through the `ModelCache`,
//! 4. widen the low-budget frontier with the sparse DARE / TALL arms:
//!    at some budget the solver picks a sparse arm and the full-set plan
//!    is no worse than the dense-arms-only plan at equal file bytes
//!    (the ISSUE-3 acceptance criterion),
//! 5. fail closed on corrupted plan / group sections, on writer misuse,
//!    and on v2 (sparse-arm) plans whose kind-4 sections are missing or
//!    of the wrong kind.

mod common;

use std::sync::Arc;

use common::fixtures::registry_sse;
use tvq::coordinator::ModelCache;
use tvq::exp::planner::synthetic_planner_zoo;
use tvq::merge::{MergedModel, Merger, TaskArithmetic};
use tvq::planner::{
    build_planned_registry, fused_merge, min_feasible_bytes, probe, solve,
    write_planned_registry, PlannerConfig, SectionRole, SectionSpec,
};
use tvq::quant::{GroupQuantized, QuantScheme, SparseGroupQuantized};
use tvq::registry::{
    build_registry, merge_from_source, DiskAccounting, PackedRegistrySource, Registry,
    RegistryBuilder, TaskVectorSource,
};
use tvq::util::exec::ExecCtx;

const N_TASKS: usize = 8;

fn tmp(name: &str) -> std::path::PathBuf {
    common::fixtures::tmp("planner_it", name)
}

#[test]
fn planned_registry_beats_uniform_rtvq_at_equal_budget() {
    let (pre, fts) = synthetic_planner_zoo(N_TASKS, 0xACCE);
    let dir = tmp("acceptance");
    std::fs::remove_dir_all(&dir).ok();

    // The uniform baseline, measured from a real file.
    let uniform_path = dir.join("rtvq3o2.qtvc");
    build_registry(&pre, &fts, QuantScheme::Rtvq(3, 2), &uniform_path).unwrap();
    let uniform = Registry::open(&uniform_path).unwrap();
    let uniform_acc = DiskAccounting::measure(&uniform).unwrap();
    let uniform_sse = registry_sse(&uniform, &pre, &fts);

    // The planner, handed exactly that file's byte cost.
    let planned_path = dir.join("planned.qtvc");
    let (plan, summary) = build_planned_registry(
        &pre,
        &fts,
        uniform_acc.file_bytes,
        &PlannerConfig::default(),
        &planned_path,
    )
    .unwrap();
    let planned = Registry::open(&planned_path).unwrap();
    let planned_acc = DiskAccounting::measure(&planned).unwrap();
    let planned_sse = registry_sse(&planned, &pre, &fts);

    // Acceptance: measured bytes <= the uniform file, error strictly lower.
    assert!(
        planned_acc.file_bytes <= uniform_acc.file_bytes,
        "planned {} B exceeds uniform RTVQ-B3O2 {} B",
        planned_acc.file_bytes,
        uniform_acc.file_bytes
    );
    assert!(
        planned_sse < uniform_sse,
        "planned SSE {planned_sse:.4e} not below uniform {uniform_sse:.4e} \
         at equal budget"
    );
    // The cost model is byte-exact against the real file.
    assert_eq!(summary.file_bytes, plan.planned_file_bytes());
    assert_eq!(summary.file_bytes, std::fs::metadata(&planned_path).unwrap().len());
    assert_eq!(planned_acc.params, pre.numel());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn budgets_are_respected_exactly_and_degrade_monotonically() {
    let (pre, fts) = synthetic_planner_zoo(4, 0xB0D6);
    let cfg = PlannerConfig { group: 256, ..PlannerConfig::default() };
    let profile = probe(&pre, &fts, &cfg).unwrap();
    let min = min_feasible_bytes(&profile);
    let dir = tmp("sweep");
    std::fs::remove_dir_all(&dir).ok();

    // Below the minimum: a pointed error, not a broken plan.
    assert!(solve(&profile, min - 1).is_err());

    let mut last_err = f64::INFINITY;
    for (i, budget) in (0..6).map(|i| min + i * min / 3).enumerate() {
        let plan = solve(&profile, budget).unwrap();
        assert!(
            plan.planned_file_bytes() <= budget,
            "step {i}: planned {} B over budget {budget} B",
            plan.planned_file_bytes()
        );
        // Each plan writes a file of exactly its planned size.
        let path = dir.join(format!("b{i}.qtvc"));
        let summary = write_planned_registry(&pre, &fts, &plan, &path).unwrap();
        assert_eq!(summary.file_bytes, plan.planned_file_bytes());
        // ...that round-trips to the same plan.
        let reg = Registry::open(&path).unwrap();
        assert_eq!(reg.plan().unwrap(), &plan);
        // Monotone degradation: more budget never means more error.
        assert!(
            plan.total_error() <= last_err,
            "step {i}: error {} regressed above {last_err}",
            plan.total_error()
        );
        last_err = plan.total_error();
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn group_sections_roundtrip_through_fused_merge_and_model_cache() {
    let (pre, fts) = synthetic_planner_zoo(N_TASKS, 0x5E7E);
    let dir = tmp("serve");
    std::fs::remove_dir_all(&dir).ok();
    let path = dir.join("planned.qtvc");
    // Dense arms only: this test pins the kind-2 group-section round
    // trip specifically (sparse kind-4 serving has its own tests).
    let cfg = PlannerConfig::dense_only();
    let profile = probe(&pre, &fts, &cfg).unwrap();
    let budget = min_feasible_bytes(&profile) * 2;
    let (plan, _) = build_planned_registry(&pre, &fts, budget, &cfg, &path).unwrap();
    let reg = Registry::open(&path).unwrap();

    // Producer -> registry: every kind-2 section decodes to the exact
    // GroupQuantized geometry the plan promised.
    for t in 0..plan.n_tasks() {
        for l in 0..plan.n_tensors() {
            let gq: GroupQuantized = reg.load_planned_task_section(t, l).unwrap();
            let tensor = &plan.tensors[l];
            assert_eq!(gq.group, tensor.group);
            assert_eq!(gq.len(), tensor.padded());
        }
    }

    // Fused dequant-merge over group sections == the generic lazy path.
    let ta = TaskArithmetic::default();
    let lams = vec![ta.lambda; N_TASKS];
    let fused = fused_merge(&reg, &pre, &lams, None, &ExecCtx::default()).unwrap();
    let mut want = pre.clone();
    for t in 0..N_TASKS {
        want.axpy(ta.lambda, &reg.load_task_vector(t, &ExecCtx::sequential()).unwrap()).unwrap();
    }
    let dist = fused.l2_dist(&want).unwrap();
    assert!(dist < 1e-3, "fused merge diverged from lazy path by {dist}");

    // Served end-to-end: ModelCache builds the variant straight from the
    // planned registry through the generic source interface.
    let source = Arc::new(PackedRegistrySource::open(&path).unwrap());
    assert_eq!(source.scheme_label(), "PLAN-MIXED");
    assert!(source.source_id().starts_with("PLAN-MIXED:"));
    let cache = ModelCache::new();
    let served = cache.get_or_build_merged(&ta, &pre, source.as_ref()).unwrap();
    let direct = merge_from_source(&ta, &pre, source.as_ref(), None, &ExecCtx::default()).unwrap();
    match (served.as_ref(), &direct) {
        (MergedModel::Shared(a), MergedModel::Shared(b)) => {
            assert_eq!(a, b, "cached variant differs from direct merge")
        }
        _ => panic!("expected shared merges"),
    }
    // And the served model is the fused result up to float association.
    match served.as_ref() {
        MergedModel::Shared(ck) => {
            let d = ck.l2_dist(&fused).unwrap();
            assert!(d < 1e-3, "served model diverged from fused merge by {d}");
        }
        _ => unreachable!(),
    }
    assert!(cache.contains(ta.name(), &source.source_id()));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sparse_arms_widen_the_low_budget_frontier() {
    // ISSUE-3 acceptance: at least one budget where the solver picks a
    // sparse (DARE or TALL) arm, with planned total SSE at that budget
    // no worse than the dense-arms-only plan at equal real file bytes;
    // byte-exactness and monotone degradation must survive the enlarged
    // arm set.
    let (pre, fts) = synthetic_planner_zoo(N_TASKS, 0x5AA5);
    let dir = tmp("sparse_frontier");
    std::fs::remove_dir_all(&dir).ok();

    let full_profile = probe(&pre, &fts, &PlannerConfig::default()).unwrap();
    let dense_profile = probe(&pre, &fts, &PlannerConfig::dense_only()).unwrap();
    let floor = min_feasible_bytes(&dense_profile);

    let mut sparse_budgets = 0usize;
    let mut last_err = f64::INFINITY;
    for (i, budget) in (0..6).map(|i| floor + i * floor / 4).enumerate() {
        let full = solve(&full_profile, budget).unwrap();
        let dense = solve(&dense_profile, budget).unwrap();
        // Monotone degradation with sparse arms in the candidate set.
        assert!(
            full.total_error() <= last_err,
            "step {i}: error {} regressed above {last_err}",
            full.total_error()
        );
        last_err = full.total_error();
        let n_sparse = full.assignments.iter().filter(|a| a.arm.is_sparse()).count();
        if n_sparse > 0 {
            sparse_budgets += 1;
            // The enlarged arm set must not lose to its dense subset at
            // the budget where it chose to go sparse.
            assert!(
                full.total_error() <= dense.total_error(),
                "budget {budget}: full-set SSE {} above dense-only {}",
                full.total_error(),
                dense.total_error()
            );
            // Byte-exactness holds for sparse plans: the written file is
            // exactly what the cost model predicted.
            let path = dir.join(format!("sparse{i}.qtvc"));
            let summary = write_planned_registry(&pre, &fts, &full, &path).unwrap();
            assert_eq!(summary.file_bytes, full.planned_file_bytes());
            assert_eq!(summary.file_bytes, std::fs::metadata(&path).unwrap().len());
            assert!(summary.file_bytes <= budget, "budget violated");
            // Round-trip: the reopened plan is the solved plan, and the
            // served reconstruction error matches the probed error.
            let reg = Registry::open(&path).unwrap();
            assert_eq!(reg.version(), 4);
            assert_eq!(reg.plan().unwrap(), &full);
            let real_sse = registry_sse(&reg, &pre, &fts);
            assert!(
                (real_sse - full.total_error()).abs()
                    <= 1e-6 * full.total_error().max(1.0),
                "probed SSE {} vs served SSE {real_sse}",
                full.total_error()
            );
        }
    }
    assert!(
        sparse_budgets > 0,
        "no budget in the sweep selected a sparse arm — the localized \
         layers should make DARE/TALL competitive"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sparse_plan_missing_or_mistyped_kind4_sections_fails_closed() {
    let (pre, fts) = synthetic_planner_zoo(2, 0x714C);
    let dir = tmp("missing_kind4");
    std::fs::remove_dir_all(&dir).ok();
    // Sparse-only candidate set: every tensor gets a kind-4 arm.
    let cfg = PlannerConfig {
        group: 256,
        tvq_bits: vec![],
        rtvq_arms: vec![],
        dare_arms: vec![],
        tall_arms: vec![(25, 4)],
    };
    let profile = probe(&pre, &fts, &cfg).unwrap();
    let plan = solve(&profile, min_feasible_bytes(&profile) * 2).unwrap();
    assert!(plan.has_sparse_arms());

    // Dummy sparse payload matching a slot's spec (open checks presence
    // and kind; geometry is checked lazily at load).
    let mk_sparse = |role| -> SparseGroupQuantized {
        match plan.section_spec(role) {
            SectionSpec::Sparse { bits, group, dense_len, survivors } => {
                let data = vec![0.1f32; dense_len];
                let keep: Vec<usize> = (0..survivors).collect();
                SparseGroupQuantized::quantize_indices(&data, &keep, 1.0, bits, group)
                    .unwrap()
            }
            other => panic!("expected a sparse spec, got {other:?}"),
        }
    };

    // 1. A v2 (sparse-arm) plan whose registry is missing one kind-4
    //    section must fail closed at open.
    let expected = plan.expected_sections();
    let mut b = RegistryBuilder::new_planned();
    b.set_plan(&plan).unwrap();
    for (name, role) in &expected[..expected.len() - 1] {
        b.add_sparse(name, &mk_sparse(*role)).unwrap();
    }
    let p = dir.join("missing.qtvc");
    b.write(&p).unwrap();
    let err = Registry::open(&p).unwrap_err().to_string();
    assert!(
        err.contains("missing") || err.contains("sections"),
        "open accepted a registry missing a kind-4 section: {err}"
    );

    // 2. Same name present but as a kind-2 group section: the offset
    //    table's kind must match the plan's arm family.
    let mut b = RegistryBuilder::new_planned();
    b.set_plan(&plan).unwrap();
    for (name, role) in &expected[..expected.len() - 1] {
        b.add_sparse(name, &mk_sparse(*role)).unwrap();
    }
    let (last_name, last_role) = &expected[expected.len() - 1];
    let SectionSpec::Sparse { bits, group, dense_len, .. } = plan.section_spec(*last_role)
    else {
        panic!("expected sparse spec");
    };
    let gq = GroupQuantized::quantize_padded(&vec![0.1f32; dense_len], bits, group).unwrap();
    b.add_group(last_name, &gq).unwrap();
    let p = dir.join("mistyped.qtvc");
    b.write(&p).unwrap();
    let err = Registry::open(&p).unwrap_err().to_string();
    assert!(
        err.contains("requires") || err.contains("kind"),
        "open accepted a kind-2 section where the plan demands kind-4: {err}"
    );

    // 3. A sparse-arm plan in a file with no kind-4 sections at all gets
    //    written as v3 — the version/arm-set pairing must reject it.
    let mut b = RegistryBuilder::new_planned();
    b.set_plan(&plan).unwrap();
    b.add_group("decoy", &gq).unwrap();
    let p = dir.join("v3_sparse_plan.qtvc");
    b.write(&p).unwrap();
    let err = Registry::open(&p).unwrap_err().to_string();
    assert!(
        err.contains("sparse arms"),
        "open accepted a v3 file whose plan uses sparse arms: {err}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupted_planned_registries_fail_closed() {
    let (pre, fts) = synthetic_planner_zoo(3, 0xC0AA);
    let dir = tmp("corrupt");
    std::fs::remove_dir_all(&dir).ok();
    let path = dir.join("planned.qtvc");
    let cfg = PlannerConfig { group: 256, ..PlannerConfig::default() };
    let profile = probe(&pre, &fts, &cfg).unwrap();
    build_planned_registry(&pre, &fts, min_feasible_bytes(&profile) * 2, &cfg, &path)
        .unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let reg = Registry::open(&path).unwrap();
    let plan_len = reg
        .entries()
        .iter()
        .find(|e| e.name == "__plan__")
        .map(|e| (e.offset, e.length))
        .unwrap();

    // A flipped byte inside the plan section is caught at open (the plan
    // is the slot/shape template — serving without it would be blind).
    let mut bad = bytes.clone();
    let plan_mid = (plan_len.0 + plan_len.1 / 2) as usize;
    bad[plan_mid] ^= 0xFF;
    let p_bad = dir.join("bad_plan.qtvc");
    std::fs::write(&p_bad, &bad).unwrap();
    assert!(Registry::open(&p_bad).is_err());

    // A flipped byte in the *last* group section leaves open() fine
    // (lazy) but fails that section's CRC on first touch.
    let mut bad2 = bytes.clone();
    let n = bad2.len();
    bad2[n - 2] ^= 0xFF;
    let p_bad2 = dir.join("bad_group.qtvc");
    std::fs::write(&p_bad2, &bad2).unwrap();
    let reg2 = Registry::open(&p_bad2).unwrap();
    let last_t = reg2.n_tasks() - 1;
    assert!(reg2.load_task_vector(last_t, &ExecCtx::sequential()).is_err());
    assert!(
        reg2.load_task_vector(0, &ExecCtx::sequential()).is_ok(),
        "untouched sections must still serve"
    );

    // Truncation inside the index is caught at open.
    let p_trunc = dir.join("trunc.qtvc");
    std::fs::write(&p_trunc, &bytes[..24]).unwrap();
    assert!(Registry::open(&p_trunc).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn planned_builder_rejects_misuse() {
    let (pre, fts) = synthetic_planner_zoo(2, 0xAB);
    // Dense-only so the mismatch subtest below exercises the section-set
    // coverage check, not the v3-vs-sparse-arm version pairing.
    let cfg = PlannerConfig { group: 256, ..PlannerConfig::dense_only() };
    let profile = probe(&pre, &fts, &cfg).unwrap();
    let plan = solve(&profile, min_feasible_bytes(&profile) * 2).unwrap();
    let dir = tmp("misuse");
    std::fs::remove_dir_all(&dir).ok();

    // Planned writes need a plan and at least one group section.
    let b = RegistryBuilder::new_planned();
    assert!(b.write(dir.join("a.qtvc")).is_err());
    let mut b = RegistryBuilder::new_planned();
    b.set_plan(&plan).unwrap();
    assert!(b.set_plan(&plan).is_err(), "double set_plan");
    assert!(b.write(dir.join("b.qtvc")).is_err(), "no group sections");

    // Uniform builders reject group sections and plans; planned builders
    // reject checkpoint payloads.
    let tau = fts[0].sub(&pre).unwrap();
    let q = tvq::quant::QuantizedCheckpoint::quantize(&tau, 3).unwrap();
    let flat = vec![0.25f32; 256];
    let gq = GroupQuantized::quantize(&flat, 3, 128).unwrap();
    let mut uniform = RegistryBuilder::new(QuantScheme::Tvq(3));
    assert!(uniform.add_group("g", &gq).is_err());
    assert!(uniform.set_plan(&plan).is_err());
    uniform.add_task("t0", &q).unwrap();
    let mut planned = RegistryBuilder::new_planned();
    assert!(planned.add_task("t0", &q).is_err());
    assert!(planned.set_rtvq_base(&q).is_err());
    assert!(planned.add_group("", &gq).is_err(), "empty name");
    planned.add_group("g", &gq).unwrap();
    assert!(planned.add_group("g", &gq).is_err(), "duplicate name");

    // A planned file whose sections don't match its plan is rejected at
    // open: write a registry with a plan but a wrong section set.
    let mut mismatched = RegistryBuilder::new_planned();
    mismatched.set_plan(&plan).unwrap();
    mismatched.add_group("not/in/plan", &gq).unwrap();
    let p = dir.join("mismatch.qtvc");
    mismatched.write(&p).unwrap();
    let err = Registry::open(&p).unwrap_err().to_string();
    assert!(
        err.contains("sections") || err.contains("missing"),
        "open accepted a plan/section mismatch: {err}"
    );
    std::fs::remove_dir_all(&dir).ok();
}
