//! Merge-method comparison: every task-vector merging method under the
//! key quantization schemes — a fast, narrower cut of paper Table 1.
//!
//! Run: `cargo run --release --example merge_methods`

use anyhow::Result;

use tvq::exp;
use tvq::exp::report::Table;
use tvq::merge::standard_methods;
use tvq::quant::QuantScheme;
use tvq::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::new()?;
    let zoo = exp::zoo(&rt, &tvq::data::VIT_S, 8)?;
    let schemes = [
        QuantScheme::Fp32,
        QuantScheme::Fq(4),
        QuantScheme::Tvq(4),
        QuantScheme::Tvq(3),
        QuantScheme::Tvq(2),
        QuantScheme::Rtvq(3, 2),
    ];
    let mut cols: Vec<String> = vec!["Method".into()];
    cols.extend(schemes.iter().map(|s| s.label()));
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "merge_methods",
        "Merging 8 tasks, vit_s: methods x schemes",
        &col_refs,
    );
    for method in standard_methods() {
        let mut row = vec![method.name().to_string()];
        let mut baseline = f64::NAN;
        for (i, &scheme) in schemes.iter().enumerate() {
            let acc =
                exp::classify::method_scheme_accuracy(&rt, &zoo, method.as_ref(), scheme)?;
            eprintln!("{} @ {}: {acc:.1}%", method.name(), scheme.label());
            if i == 0 {
                baseline = acc;
                row.push(format!("{acc:.1}"));
            } else {
                row.push(Table::cell_with_delta(acc, baseline));
            }
        }
        table.push_row(row);
    }
    table.print();
    table.save()?;
    Ok(())
}
