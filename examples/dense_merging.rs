//! Dense-prediction merging: the NYUv2-analog pipeline (segmentation,
//! depth estimation, normal estimation) under TVQ/RTVQ — a fast cut of
//! paper Table 3.
//!
//! Run: `cargo run --release --example dense_merging`

use anyhow::Result;

use tvq::data::dense::DenseTaskKind;
use tvq::exp;
use tvq::exp::report::Table;
use tvq::merge::{Merger, TaskArithmetic, Ties};
use tvq::quant::QuantScheme;
use tvq::runtime::Runtime;
use tvq::train::DenseZoo;

fn main() -> Result<()> {
    let rt = Runtime::new()?;
    let zoo = DenseZoo::build_or_load(&rt, &exp::default_train_config())?;
    let fts: Vec<_> = zoo.fts.iter().map(|(_, ck)| ck.clone()).collect();

    let schemes = [
        QuantScheme::Fp32,
        QuantScheme::Tvq(4),
        QuantScheme::Tvq(2),
        QuantScheme::Rtvq(2, 2),
    ];
    let methods: Vec<Box<dyn Merger>> =
        vec![Box::new(TaskArithmetic::default()), Box::new(Ties::default())];

    let mut cols: Vec<String> = vec!["Method / Task".into()];
    cols.extend(schemes.iter().map(|s| s.label()));
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "dense_merging",
        "Dense prediction merging (mIoU up / RelErr down / MeanAngle down)",
        &col_refs,
    );

    for method in &methods {
        for (ki, kind) in DenseTaskKind::all().iter().enumerate() {
            let mut row = vec![format!("{} / {}", method.name(), kind.name())];
            for &scheme in &schemes {
                let st = exp::scheme_taus(&zoo.pre, &fts, scheme)?;
                let merged = method.merge(&zoo.pre, &st.taus)?;
                let scores = tvq::eval::dense_eval(
                    &rt,
                    &zoo.preset,
                    merged.for_task(ki),
                    *kind,
                    zoo.head(*kind),
                    4,
                )?;
                let v = exp::dense::headline(&scores, *kind);
                eprintln!("{} {} @ {}: {v:.1}", method.name(), kind.name(), scheme.label());
                row.push(format!("{v:.1}"));
            }
            table.push_row(row);
        }
    }
    table.print();
    table.save()?;
    Ok(())
}
