//! End-to-end driver: the full system on one real (synthetic-data)
//! workload, proving all three layers compose.
//!
//!   1. TRAIN   — pre-train a ViT trunk, then fine-tune 8 task
//!                checkpoints through the AOT PJRT train-step artifact
//!                (L2 JAX graph + L1 Pallas kernels), logging loss curves.
//!   2. QUANTIZE — TVQ-INT3 and RTVQ-B3O2 the task vectors; report
//!                storage and quantization error (the paper's headline).
//!   3. MERGE   — task arithmetic + EMR on FP32 vs quantized vectors.
//!   4. EVALUATE — per-task accuracy of each merged variant.
//!   5. SERVE   — boot the coordinator on the quantized merged model and
//!                push concurrent traffic; report latency/throughput.
//!
//! Results from a reference run are recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `cargo run --release --example end_to_end`

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use tvq::coordinator::{Server, ServerConfig, ServeModel};
use tvq::data::classify::TaskSuite;
use tvq::data::VIT_S;
use tvq::exp;
use tvq::merge::{EmrMerging, Merger, TaskArithmetic};
use tvq::quant::{QuantScheme, Rtvq, QuantizedCheckpoint};
use tvq::runtime::Runtime;
use tvq::tensor::Tensor;
use tvq::train::{self, TrainConfig};
use tvq::util::exec::ExecCtx;
use tvq::util::rng::Rng;

fn main() -> Result<()> {
    let rt = Runtime::new()?;
    let preset = &VIT_S;
    let n_tasks = 8;
    let cfg = TrainConfig::default();

    // ---------------------------------------------------------- 1. TRAIN
    println!("== 1. training (PJRT, {} steps/task) ==", cfg.steps);
    let suite = TaskSuite::new(preset, n_tasks, 1000);
    let t_train = Instant::now();
    let (pre, pre_losses) =
        train::pretrain_classify(&rt, preset, &suite.pretrain_task(), &cfg, 0x9E3)?;
    print_curve("pretrain", &pre_losses);
    let mut fts = Vec::with_capacity(n_tasks);
    for (i, task) in suite.tasks.iter().enumerate() {
        let (ft, losses) = train::finetune_classify(&rt, preset, &pre, task, &cfg)?;
        print_curve(&format!("task{i:02}"), &losses);
        fts.push(ft);
    }
    println!("training wall-clock: {:.1}s", t_train.elapsed().as_secs_f64());

    // ------------------------------------------------------ 2. QUANTIZE
    println!("\n== 2. quantization ==");
    let fp32_bytes = n_tasks * pre.fp32_bytes();
    for scheme in [QuantScheme::Tvq(3), QuantScheme::Rtvq(3, 2)] {
        let st = exp::scheme_taus(&pre, &fts, scheme)?;
        let err: f64 = fts
            .iter()
            .zip(&st.taus)
            .map(|(ft, tau_hat)| {
                ft.sub(&pre).unwrap().l2_dist(tau_hat).unwrap()
            })
            .sum();
        println!(
            "{:<10}: {} B ({:.1}% of fp32), total L2 err {err:.4}, {:.3} bits/task",
            scheme.label(),
            st.storage_bytes,
            100.0 * st.storage_bytes as f64 / fp32_bytes as f64,
            scheme.effective_bits(n_tasks)
        );
    }
    // Sanity: the two core quantizers round-trip within their bound.
    let tau0 = fts[0].sub(&pre)?;
    let q = QuantizedCheckpoint::quantize(&tau0, 3)?;
    println!("TVQ-INT3 task0 L2 err: {:.5}", q.quant_error(&tau0)?);
    let r = Rtvq::quantize(&pre, &fts, 3, 2, true, &ExecCtx::sequential())?;
    println!("RTVQ-B3O2 total err:   {:.5}", r.total_quant_error(&pre, &fts)?);

    // ------------------------------------------------ 3+4. MERGE + EVAL
    println!("\n== 3/4. merge + evaluate ==");
    let methods: Vec<Box<dyn Merger>> =
        vec![Box::new(TaskArithmetic::default()), Box::new(EmrMerging)];
    let schemes = [QuantScheme::Fp32, QuantScheme::Tvq(3), QuantScheme::Rtvq(3, 2)];
    let mut emr_tvq3 = None;
    for method in &methods {
        for &scheme in &schemes {
            let st = exp::scheme_taus(&pre, &fts, scheme)?;
            let merged = method.merge(&pre, &st.taus)?;
            let mut accs = Vec::new();
            for (t, task) in suite.tasks.iter().enumerate() {
                accs.push(tvq::eval::classify_accuracy(
                    &rt,
                    preset,
                    merged.for_task(t),
                    task,
                )?);
            }
            let avg = accs.iter().sum::<f64>() / accs.len() as f64;
            println!("{:<16} @ {:<10}: avg acc {avg:.1}%", method.name(), scheme.label());
            if method.name() == "emr_merging" && scheme == QuantScheme::Tvq(3) {
                emr_tvq3 = Some(merged);
            }
        }
    }

    // ---------------------------------------------------------- 5. SERVE
    println!("\n== 5. serve (coordinator, quantized EMR variant) ==");
    let merged = Arc::new(emr_tvq3.expect("emr @ tvq3 built above"));
    let heads = Arc::new(suite.tasks.iter().map(|t| t.head.clone()).collect::<Vec<_>>());
    let model = ServeModel { preset, merged, heads };
    let cfg = ServerConfig {
        max_batch: 32,
        max_delay: Duration::from_millis(2),
        queue_cap: 4096,
        executors: 2,
    };
    let server = Arc::new(Server::start(cfg, model)?);
    // Warm every serve bucket (first PJRT compile is 100s of ms), then
    // reset the latency window so percentiles reflect steady state.
    {
        let mut rng = Rng::new(0xAA);
        for burst in [1usize, 8, 32, 32] {
            let rxs: Vec<_> = (0..burst)
                .map(|_| {
                    let x =
                        Tensor::randn(&[VIT_S.tokens, VIT_S.token_dim], 1.0, &mut rng);
                    server.submit(0, &x).unwrap()
                })
                .collect();
            for rx in rxs {
                rx.recv().unwrap().map_err(anyhow::Error::msg)?;
            }
        }
        server.reset_metrics_window();
    }
    let clients = 8;
    let per_client = 128;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let s = server.clone();
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut rng = Rng::new(0xE2E + c as u64);
            for _ in 0..per_client {
                let task = rng.below(8);
                let x = Tensor::randn(&[VIT_S.tokens, VIT_S.token_dim], 1.0, &mut rng);
                s.infer(task, &x)?;
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().expect("client panicked")?;
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = server.metrics();
    println!("{}", m.summary());
    println!(
        "throughput {:.0} req/s | wall {dt:.2}s | python on request path: never",
        m.completed as f64 / dt
    );
    Ok(())
}

fn print_curve(name: &str, losses: &[f32]) {
    let pts: Vec<String> = losses
        .iter()
        .enumerate()
        .filter(|(i, _)| i % 50 == 0 || *i == losses.len() - 1)
        .map(|(i, l)| format!("{i}:{l:.3}"))
        .collect();
    println!("  {name} loss curve: {}", pts.join(" -> "));
}
