//! Packed-registry serving demo: quantized task vectors as the durable
//! artifact.
//!
//! Builds a synthetic 8-task zoo, persists it both ways — raw f32 `TVQC`
//! checkpoints and packed `QTVC` v2 registries (TVQ-INT4, RTVQ-B3O2) —
//! compares real on-disk bytes against the paper's ideal arithmetic,
//! then **deletes the f32 zoo** and serves a merged variant built through
//! the `ModelCache` from packed payloads alone, loading only the tasks
//! the merge request names.
//!
//! Run: `cargo run --release --example packed_registry`
//!
//! With `TVQ_TRACE=/tmp/trace.json` set, the run records spans across
//! the registry / merge / cache / control layers and exports a Chrome
//! trace-event file at exit (open in chrome://tracing or Perfetto).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use tvq::checkpoint::{Checkpoint, CheckpointStore};
use tvq::coordinator::{Metrics, ModelCache, Server, ServerConfig};
use tvq::data::VIT_S;
use tvq::merge::{EmrMerging, MergedModel, TaskArithmetic};
use tvq::quant::QuantScheme;
use tvq::registry::{
    build_registry, f32_store_bytes, DiskAccounting, PackedRegistrySource, Registry,
    TaskVectorSource,
};
use tvq::tensor::Tensor;
use tvq::util::exec::ExecCtx;
use tvq::util::rng::Rng;

const N_TASKS: usize = 8;

fn synth_zoo(seed: u64) -> (Checkpoint, Vec<Checkpoint>) {
    let mut rng = Rng::new(seed);
    let mut pre = Checkpoint::new();
    for blk in 0..4 {
        pre.insert(&format!("blk{blk:02}/w"), Tensor::randn(&[256, 192], 0.3, &mut rng));
    }
    pre.insert("head/b", Tensor::randn(&[192], 0.1, &mut rng));
    let fts = (0..N_TASKS)
        .map(|_| {
            let mut tau = Checkpoint::new();
            for (name, t) in pre.iter() {
                tau.insert(name, Tensor::randn(t.shape(), 0.008, &mut rng));
            }
            pre.add(&tau).unwrap()
        })
        .collect();
    (pre, fts)
}

/// PJRT-free executor: proves the merged trunk was materialized from the
/// registry by folding its parameter checksum into every logit row.
struct ChecksumBackend {
    merged: Arc<MergedModel>,
}

impl tvq::coordinator::server::Backend for ChecksumBackend {
    fn infer(&mut self, task: usize, x: &Tensor, n_valid: usize) -> Result<Vec<Vec<f32>>> {
        let trunk = self.merged.for_task(task);
        let checksum: f32 = trunk
            .iter()
            .map(|(_, t)| t.data().iter().sum::<f32>())
            .sum();
        let img = x.numel() / x.shape()[0];
        Ok((0..n_valid)
            .map(|i| {
                let s: f32 = x.data()[i * img..(i + 1) * img].iter().sum();
                vec![s + checksum, task as f32]
            })
            .collect())
    }
}

fn main() -> Result<()> {
    // Span tracing: honour TVQ_TRACE=<out.json> (the `tvq` binary's
    // global `--trace` flag is the CLI equivalent).
    let trace_out = tvq::obs::trace::init_from_env();
    let (pre, fts) = synth_zoo(0x9E61);
    let dir = std::env::temp_dir().join("tvq_packed_registry_demo");
    std::fs::remove_dir_all(&dir).ok();

    // -- 1. persist both durable forms ------------------------------------
    let store = CheckpointStore::new(dir.join("f32"));
    for (t, ft) in fts.iter().enumerate() {
        store.save(&format!("task{t:02}"), ft)?;
    }
    let f32_bytes = f32_store_bytes(&store)?;
    println!(
        "f32 zoo (TVQC v1): {N_TASKS} tasks x {} params = {:.2} MiB on disk",
        pre.numel(),
        f32_bytes as f64 / (1024.0 * 1024.0)
    );

    println!("\npacked registries (QTVC v2):");
    for scheme in [QuantScheme::Tvq(4), QuantScheme::Rtvq(3, 2)] {
        let path = dir.join(format!("{}.qtvc", scheme.label()));
        let t0 = Instant::now();
        build_registry(&pre, &fts, scheme, &path)?;
        let reg = Registry::open(&path)?;
        let acc = DiskAccounting::measure(&reg)?;
        println!(
            "  {:<10} {:>9} B on disk  (ideal {:>9} B, +{:.2}% metadata) \
             = {:>5.1}% of f32 files   [packed in {:.0} ms]",
            scheme.label(),
            acc.file_bytes,
            acc.ideal_bytes,
            100.0 * acc.overhead_fraction(),
            100.0 * acc.file_bytes as f64 / f32_bytes as f64,
            t0.elapsed().as_secs_f64() * 1e3,
        );
    }

    // -- 2. the f32 zoo is no longer needed: delete it ---------------------
    std::fs::remove_dir_all(dir.join("f32"))?;
    println!("\nf32 zoo deleted — everything below runs off packed payloads.");

    // -- 3. lazy loading: open reads the index only ------------------------
    let tvq_path = dir.join("TVQ-INT4.qtvc");
    let reg = Registry::open(&tvq_path)?;
    println!(
        "opened {}: {} tasks, index {} B of {} B total",
        tvq_path.file_name().unwrap().to_string_lossy(),
        reg.n_tasks(),
        reg.index_bytes(),
        reg.file_bytes()
    );
    let t0 = Instant::now();
    let tau3 = reg.load_task_vector(3, &ExecCtx::sequential())?;
    println!(
        "lazy-loaded task03 ({} params) in {:.1} ms — other sections untouched",
        tau3.numel(),
        t0.elapsed().as_secs_f64() * 1e3
    );

    // -- 4. warm a variant cache straight from packed payloads -------------
    let cache = Arc::new(ModelCache::new());
    // Merge builds run chunk-parallel on the shared worker pool; a
    // metrics sink makes the realized speedup (pool busy / wall time)
    // observable below.
    let build_metrics = Arc::new(Metrics::new());
    cache.set_metrics(build_metrics.clone());
    let source = Arc::new(PackedRegistrySource::open(&tvq_path)?);
    let rtvq_source = Arc::new(PackedRegistrySource::open(dir.join("RTVQ-B3O2.qtvc"))?);
    let t0 = Instant::now();
    cache.get_or_build_merged(&TaskArithmetic::default(), &pre, source.as_ref())?;
    cache.get_or_build_merged(&TaskArithmetic::default(), &pre, rtvq_source.as_ref())?;
    cache.get_or_build_merged(&EmrMerging, &pre, source.as_ref())?;
    let builds = build_metrics.snapshot();
    println!(
        "\nmodel cache: {} variants built from packed payloads in {:.0} ms \
         ({:.1} MiB fp32 resident; {} builds, x{:.2} parallel on {} threads)",
        cache.len(),
        t0.elapsed().as_secs_f64() * 1e3,
        cache.resident_bytes() as f64 / (1024.0 * 1024.0),
        builds.merge_builds,
        builds.merge_build_speedup(),
        tvq::util::pool::Pool::global().threads(),
    );
    for (m, s) in cache.keys() {
        println!("  {m} @ {s}");
    }

    // -- 5. serve the TA @ TVQ-INT4 variant under concurrent load ----------
    let merged = cache.get_or_build_merged(&TaskArithmetic::default(), &pre, source.as_ref())?;
    let served = merged.clone();
    let server = Arc::new(Server::start_with_backend(
        ServerConfig::default(),
        &VIT_S,
        N_TASKS,
        move || Ok(ChecksumBackend { merged: served.clone() }),
    )?);
    let clients = 4;
    let per_client = 64;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let s = server.clone();
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut rng = Rng::new(0xC0DE + c as u64);
            for _ in 0..per_client {
                let task = rng.below(N_TASKS);
                let x = Tensor::randn(&[VIT_S.tokens, VIT_S.token_dim], 1.0, &mut rng);
                let logits = s.infer(task, &x)?;
                anyhow::ensure!(logits[1] == task as f32, "routed to wrong task");
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().expect("client thread panicked")?;
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = server.metrics();
    println!(
        "\nserved {} requests from the packed-registry variant in {dt:.2}s ({:.0} req/s)",
        m.completed,
        m.completed as f64 / dt
    );
    println!("scheme served: {}", source.scheme_label());

    // -- 6. control plane: lifecycle-managed variant over the same file ----
    // (Also gives a TVQ_TRACE run its control-category spans: admit,
    // service, drain.)
    use tvq::coordinator::control::{ControlPlane, VariantConfig, VariantState};
    let plane = ControlPlane::new(Arc::new(ModelCache::new()));
    let variant = plane
        .load_variant("demo", &tvq_path, &VariantConfig::default())
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let rx = variant.submit_task_vector(1).map_err(|e| anyhow::anyhow!("{e}"))?;
    let tau = rx.recv()??;
    plane.drain_variant("demo", None).map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(
        variant.await_state(&VariantState::Terminated, std::time::Duration::from_secs(10)),
        "variant did not terminate"
    );
    println!(
        "control plane: variant admitted, reconstructed task01 ({} params), drained cleanly",
        tau.numel()
    );

    std::fs::remove_dir_all(&dir).ok();
    if let Some(path) = trace_out {
        tvq::obs::trace::flush_env()?;
        println!(
            "trace: wrote {} spans to {path} ({} dropped by ring caps)",
            tvq::obs::trace::events().len(),
            tvq::obs::trace::dropped()
        );
    }
    Ok(())
}
