//! Serving demo: the coordinator under concurrent load with a warm
//! merged-model cache holding several (method, scheme) variants.
//!
//! Shows the deployment story the paper's storage numbers enable: many
//! compact quantized variants resident at once, batched multi-task
//! inference with Python nowhere on the request path.
//!
//! Run: `cargo run --release --example serving`

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use tvq::coordinator::{ModelCache, Server, ServerConfig, ServeModel};
use tvq::exp;
use tvq::merge::{EmrMerging, Merger, TaskArithmetic};
use tvq::quant::QuantScheme;
use tvq::runtime::Runtime;
use tvq::tensor::Tensor;
use tvq::util::rng::Rng;

fn main() -> Result<()> {
    let rt = Runtime::new()?;
    let zoo = exp::zoo(&rt, &tvq::data::VIT_S, 8)?;

    // Warm a cache of merged variants (shared pre-trained trunk; each
    // variant built from quantized task vectors).
    let cache = ModelCache::new();
    let variants: Vec<(&str, Box<dyn Merger>, QuantScheme)> = vec![
        ("ta", Box::new(TaskArithmetic::default()), QuantScheme::Tvq(3)),
        ("ta", Box::new(TaskArithmetic::default()), QuantScheme::Rtvq(3, 2)),
        ("emr", Box::new(EmrMerging), QuantScheme::Tvq(3)),
    ];
    for (name, method, scheme) in &variants {
        let st = exp::scheme_taus(&zoo.pre, &zoo.fts, *scheme)?;
        cache.get_or_build(name, &scheme.label(), || method.merge(&zoo.pre, &st.taus))?;
    }
    println!(
        "model cache: {} variants resident, {:.1} MiB fp32",
        cache.len(),
        cache.resident_bytes() as f64 / (1024.0 * 1024.0)
    );
    for (m, s) in cache.keys() {
        println!("  {m} @ {s}");
    }

    // Serve the EMR @ TVQ-INT3 variant (per-task masked models).
    let merged = cache.get_or_build("emr", "TVQ-INT3", || unreachable!())?;
    let heads = Arc::new(
        zoo.suite.tasks.iter().map(|t| t.head.clone()).collect::<Vec<_>>(),
    );
    let model = ServeModel { preset: zoo.preset, merged, heads };
    let cfg = ServerConfig {
        max_batch: 32,
        max_delay: Duration::from_millis(2),
        queue_cap: 4096,
        executors: 2,
    };
    let server = Arc::new(Server::start(cfg, model)?);

    // Load: 8 client threads, mixed tasks, closed loop.
    let clients = 8;
    let per_client = 128;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let s = server.clone();
        let n_tasks = zoo.suite.tasks.len();
        let preset = zoo.preset;
        handles.push(std::thread::spawn(move || -> Result<u32> {
            let mut rng = Rng::new(0xC11E + c as u64);
            let mut ok = 0;
            for _ in 0..per_client {
                let task = rng.below(n_tasks);
                let x = Tensor::randn(&[preset.tokens, preset.token_dim], 1.0, &mut rng);
                let logits = s.infer(task, &x)?;
                assert_eq!(logits.len(), preset.n_classes);
                ok += 1;
            }
            Ok(ok)
        }));
    }
    let mut total = 0;
    for h in handles {
        total += h.join().expect("client thread panicked")?;
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = server.metrics();
    println!("\nserved {total} requests in {dt:.2}s  ({:.0} req/s)", total as f64 / dt);
    println!("{}", m.summary());
    Ok(())
}
