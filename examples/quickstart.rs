//! Quickstart: the paper's core idea in ~60 lines.
//!
//! 1. Build (or load the cached) 8-task checkpoint zoo.
//! 2. Show the Fig. 3 observation: the task vector's weight range is an
//!    order of magnitude narrower than the fine-tuned checkpoint's.
//! 3. Quantize the task vector at 3 bits (TVQ) vs quantizing the full
//!    checkpoint (FQ) — compare quantization error and storage.
//! 4. Merge all 8 quantized task vectors with task arithmetic and report
//!    multi-task accuracy against the FP32 baseline.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;

use tvq::exp;
use tvq::merge::{Merger, TaskArithmetic};
use tvq::quant::{QuantScheme, QuantizedCheckpoint};
use tvq::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::new()?;
    println!("PJRT platform: {}", rt.platform());

    // 1. Checkpoint zoo (cached under target/zoo after the first build).
    let zoo = exp::zoo(&rt, &tvq::data::VIT_S, 8)?;
    println!(
        "zoo: {} tasks x {} params ({:.1} KiB fp32 per checkpoint)",
        zoo.n_tasks(),
        zoo.pre.numel(),
        zoo.pre.fp32_bytes() as f64 / 1024.0
    );

    // 2. The observation (paper Fig. 3).
    let ft = &zoo.fts[0];
    let tau = ft.sub(&zoo.pre)?;
    let (flo, fhi) = ft.weight_range();
    let (tlo, thi) = tau.weight_range();
    println!(
        "\nweight ranges (task 0):\n  fine-tuned ckpt: [{flo:.3}, {fhi:.3}]  width {:.3}\n  task vector:     [{tlo:.4}, {thi:.4}]  width {:.4}  ({:.0}x narrower)",
        fhi - flo,
        thi - tlo,
        (fhi - flo) / (thi - tlo)
    );

    // 3. TVQ vs FQ at 3 bits (paper Fig. 4 / Section 4.2).
    let q_tau = QuantizedCheckpoint::quantize(&tau, 3)?;
    let q_ft = QuantizedCheckpoint::quantize(ft, 3)?;
    let tvq_err = q_tau.quant_error(&tau)?;
    let fq_err = q_ft.dequantize()?.sub(&zoo.pre)?.l2_dist(&tau)?;
    println!(
        "\n3-bit quantization error (L2 on the task vector):\n  TVQ: {tvq_err:.4}\n  FQ:  {fq_err:.4}   ({:.0}x worse)",
        fq_err / tvq_err
    );
    println!(
        "storage per checkpoint: fp32 {} B -> TVQ-INT3 {} B ({:.1}%)",
        tau.fp32_bytes(),
        q_tau.storage_bytes(),
        100.0 * q_tau.storage_bytes() as f64 / tau.fp32_bytes() as f64
    );

    // 4. Merge 8 quantized task vectors and evaluate (paper Table 1 cell).
    let ta = TaskArithmetic::default();
    for scheme in [QuantScheme::Fp32, QuantScheme::Tvq(3), QuantScheme::Rtvq(3, 2)] {
        let st = exp::scheme_taus(&zoo.pre, &zoo.fts, scheme)?;
        let merged = ta.merge(&zoo.pre, &st.taus)?;
        let accs = exp::classify::eval_merged(&rt, &zoo, &merged)?;
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        println!(
            "task arithmetic @ {:<10}: avg accuracy {avg:.1}%  (storage {:.1}% of fp32)",
            scheme.label(),
            100.0 * st.storage_bytes as f64 / (8 * zoo.pre.fp32_bytes()) as f64
        );
    }
    Ok(())
}
