"""Packed-codes dequant-merge kernel vs oracles (Layer 1 extension)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import packed_merge as pm
from compile.kernels import ref

BITS = [2, 4, 8]


def _codes(t, n, bits, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2 ** bits, size=(t, n)).astype(np.int32))


@pytest.mark.parametrize("bits", BITS)
def test_pack_unpack_roundtrip(bits):
    q = _codes(3, 4096, bits)
    w = pm.pack_codes(q, bits)
    assert w.dtype == jnp.int32
    assert w.shape == (3, 4096 * bits // 32)
    back = pm.unpack_codes(w, bits, 4096)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


@pytest.mark.parametrize("bits", BITS)
def test_packed_kernel_matches_unpacked_ref(bits):
    """The packed Pallas kernel must equal unpack + dequant_merge_ref."""
    t, n = 4, 4096
    g = n // pm.BLOCK
    rng = np.random.default_rng(1)
    pre = jnp.asarray(rng.normal(0, 0.3, n).astype(np.float32))
    q = _codes(t, n, bits, seed=2)
    scales = jnp.asarray(rng.uniform(1e-3, 1e-2, (t, g)).astype(np.float32))
    zps = jnp.asarray(rng.integers(0, 2 ** bits, (t, g)).astype(np.float32))
    lams = jnp.asarray(rng.uniform(0, 1, t).astype(np.float32))
    words = pm.pack_codes(q, bits)

    got = pm.packed_dequant_merge(pre, words, scales, zps, lams, bits=bits)
    want = ref.dequant_merge_ref(pre, q.astype(jnp.float32), scales, zps, lams)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", BITS)
def test_packed_ref_matches_kernel(bits):
    """And the pure-jnp packed oracle agrees with the kernel too."""
    t, n = 2, 2048
    g = n // pm.BLOCK
    rng = np.random.default_rng(3)
    pre = jnp.asarray(rng.normal(0, 0.3, n).astype(np.float32))
    q = _codes(t, n, bits, seed=4)
    scales = jnp.asarray(rng.uniform(1e-3, 1e-2, (t, g)).astype(np.float32))
    zps = jnp.asarray(rng.integers(0, 2 ** bits, (t, g)).astype(np.float32))
    lams = jnp.asarray(rng.uniform(0, 1, t).astype(np.float32))
    words = pm.pack_codes(q, bits)
    a = pm.packed_dequant_merge(pre, words, scales, zps, lams, bits=bits)
    b = pm.packed_dequant_merge_ref(pre, words, scales, zps, lams, bits=bits)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_rejects_bad_bits():
    with pytest.raises(ValueError):
        pm.pack_codes(_codes(1, 32, 2), 3)
    with pytest.raises(ValueError):
        pm.packed_dequant_merge(
            jnp.zeros(1024), jnp.zeros((1, 96), jnp.int32),
            jnp.ones((1, 1)), jnp.zeros((1, 1)), jnp.ones(1), bits=3,
        )


def test_payload_shrinks_by_32_over_bits():
    q = _codes(1, 1024, 2)
    w = pm.pack_codes(q, 2)
    assert w.size * 4 == 1024 * 2 // 8  # 2-bit payload in bytes


@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(1, 4),
    blocks=st.integers(1, 3),
    bits=st.sampled_from(BITS),
    seed=st.integers(0, 2 ** 16),
)
def test_hypothesis_packed_sweep(t, blocks, bits, seed):
    n = blocks * pm.BLOCK
    g = blocks
    rng = np.random.default_rng(seed)
    pre = jnp.asarray(rng.normal(0, 0.3, n).astype(np.float32))
    q = _codes(t, n, bits, seed=seed + 1)
    scales = jnp.asarray(rng.uniform(1e-4, 1e-1, (t, g)).astype(np.float32))
    zps = jnp.asarray(rng.integers(0, 2 ** bits, (t, g)).astype(np.float32))
    lams = jnp.asarray(rng.uniform(0, 1, t).astype(np.float32))
    words = pm.pack_codes(q, bits)
    got = pm.packed_dequant_merge(pre, words, scales, zps, lams, bits=bits)
    want = ref.dequant_merge_ref(pre, q.astype(jnp.float32), scales, zps, lams)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
