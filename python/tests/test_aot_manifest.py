"""AOT manifest contract checks (no lowering — validates emitted files)."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "index.json")),
    reason="artifacts not built (run `make artifacts`)",
)


def _load(name):
    with open(os.path.join(ART, f"{name}.json")) as f:
        return json.load(f)


def test_index_covers_every_artifact_spec():
    with open(os.path.join(ART, "index.json")) as f:
        index = json.load(f)
    specs = {a.name for a in aot.all_artifacts()}
    assert specs == set(index.keys())


@pytest.mark.parametrize("preset", list(M.VIT_PRESETS))
def test_param_manifest_matches_model(preset):
    cfg = M.VIT_PRESETS[preset]
    p = M.vit_init(cfg)
    man = _load(f"{preset}_forward_b1")
    names = [e["name"] for e in man["params"]]
    assert names == M.param_order(p)
    for e in man["params"]:
        assert tuple(e["shape"]) == tuple(p[e["name"]].shape)
    assert man["meta"]["param_count"] == M.param_count(p)
    assert man["meta"]["flat_padded"] == M.flat_size_padded(p)


def test_train_manifest_outputs_are_params_plus_loss():
    man = _load(f"vit_s_train_b{aot.TRAIN_BATCH}")
    n_params = len(man["params"])
    assert len(man["outputs"]) == n_params + 1
    # last output is the scalar loss
    assert man["outputs"][-1]["shape"] in ([], [1])
    # first outputs mirror param shapes in manifest order
    for e, o in zip(man["params"], man["outputs"][:n_params]):
        assert tuple(e["shape"]) == tuple(o["shape"])


def test_forward_manifest_input_order():
    man = _load("vit_s_forward_b8")
    names = [i["name"] for i in man["inputs"]]
    n = len(man["params"])
    assert names[:n] == [f"param:{e['name']}" for e in man["params"]]
    assert names[n:] == ["head", "x"]


def test_hlo_files_exist_and_hash():
    import hashlib

    with open(os.path.join(ART, "index.json")) as f:
        index = json.load(f)
    for name in index:
        man = _load(name)
        path = os.path.join(ART, f"{name}.hlo.txt")
        with open(path) as f:
            text = f.read()
        assert hashlib.sha256(text.encode()).hexdigest() == man["hlo_sha256"]
        assert "ENTRY" in text  # parseable HLO text


def test_merged_forward_manifest_geometry():
    man = _load(f"vit_s_merged_forward_t{aot.MERGE_TASKS}_b32")
    meta = man["meta"]
    npad = meta["flat_padded"]
    g = npad // meta["block"]
    shapes = {i["name"]: i["shape"] for i in man["inputs"]}
    assert shapes["pre_flat"] == [npad]
    assert shapes["q"] == [aot.MERGE_TASKS, npad]
    assert shapes["scales"] == [aot.MERGE_TASKS, g]
    assert shapes["zps"] == [aot.MERGE_TASKS, g]


def test_dense_manifests_cover_all_tasks():
    for task in M.DENSE_TASKS:
        fwd = _load(f"dense_forward_{task}_b{aot.DENSE_BATCH}")
        tr = _load(f"dense_train_{task}_b{aot.DENSE_BATCH}")
        assert fwd["meta"]["task"] == task
        assert tr["meta"]["task"] == task
        out_ch = M.DENSE_TASKS[task]
        assert fwd["outputs"][0]["shape"][-1] == out_ch
