"""Pallas quantize kernel vs pure-jnp oracle (the core L1 signal)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import quantize as qz
from compile.kernels import ref

BITS = [2, 3, 4, 8]


def _rand(n, seed=0, scale=0.05):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0.0, scale, size=n).astype(np.float32))


@pytest.mark.parametrize("bits", BITS)
def test_quantize_matches_ref(bits):
    n = 4096
    x = _rand(n)
    qmax = float(2 ** bits - 1)
    q, s, z = qz.quantize(x, jnp.array([qmax], jnp.float32))
    sr, zr = ref.group_quant_params_ref(x, n // qz.BLOCK, qmax)
    qr = ref.group_quantize_ref(x, sr, zr, qmax)
    np.testing.assert_allclose(s, sr, rtol=1e-6)
    np.testing.assert_allclose(z, zr, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))


@pytest.mark.parametrize("bits", BITS)
def test_quantized_values_in_range(bits):
    x = _rand(8192, seed=1)
    qmax = float(2 ** bits - 1)
    q, _, _ = qz.quantize(x, jnp.array([qmax], jnp.float32))
    assert float(jnp.min(q)) >= 0.0
    assert float(jnp.max(q)) <= qmax
    # values are integers stored as f32
    np.testing.assert_array_equal(np.asarray(q), np.round(np.asarray(q)))


@pytest.mark.parametrize("bits", BITS)
def test_roundtrip_error_bound(bits):
    """|x - dq(q(x))| <= scale/2 per group (Eq. 3), + fp slack."""
    n = 4096
    x = _rand(n, seed=2)
    qmax = float(2 ** bits - 1)
    q, s, z = qz.quantize(x, jnp.array([qmax], jnp.float32))
    g = n // qz.BLOCK
    xh = (np.asarray(q).reshape(g, -1) - np.asarray(z)[:, None]) \
        * np.asarray(s)[:, None]
    err = np.abs(xh.reshape(-1) - np.asarray(x))
    bound = np.repeat(np.asarray(s) / 2.0, qz.BLOCK) * (1.0 + 1e-4) + 1e-7
    assert (err <= bound).all()


def test_constant_tensor_exact():
    """Degenerate range: constants must round-trip exactly."""
    x = jnp.full((2048,), 0.017, jnp.float32)
    q, s, z = qz.quantize(x, jnp.array([3.0], jnp.float32))
    xh = np.asarray(s)[:, None] * (np.asarray(q).reshape(2, -1)
                                   - np.asarray(z)[:, None])
    np.testing.assert_allclose(xh.reshape(-1), np.asarray(x), atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=6),
    bits=st.sampled_from(BITS),
    seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
    scale=st.floats(min_value=1e-4, max_value=10.0),
)
def test_hypothesis_quantize_sweep(blocks, bits, seed, scale):
    """Shape/range sweep: Pallas kernel == oracle for arbitrary inputs."""
    n = blocks * qz.BLOCK
    x = _rand(n, seed=seed, scale=scale)
    qmax = float(2 ** bits - 1)
    q, s, z = qz.quantize(x, jnp.array([qmax], jnp.float32))
    sr, zr = ref.group_quant_params_ref(x, blocks, qmax)
    qr = ref.group_quantize_ref(x, sr, zr, qmax)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
