"""Cross-runtime byte parity: Rust-packed sections vs the Python kernels.

``cargo test --test simd_parity`` (the ``export_python_parity_fixtures``
test) writes Rust-packed section payloads plus the Rust scalar-kernel
decode as f32 goldens under ``target/parity/``.  This suite decodes the
same bytes through ``packed_merge`` (kind-2 dense) and a numpy replay of
the sparse scatter (kind-4) and asserts the floats are **byte**-equal —
not allclose — pinning the wire format and the dequant arithmetic across
the two runtimes.

Skips pointedly when the fixture has not been generated or when jax is
unavailable in this environment.
"""

import json
import os
from pathlib import Path

import numpy as np
import pytest

jax = pytest.importorskip("jax", reason="jax not installed; packed_merge parity needs it")
jnp = jax.numpy

from compile.kernels import packed_merge as pm  # noqa: E402


def _fixture_dir() -> Path:
    env = os.environ.get("TVQ_PARITY_DIR")
    if env:
        return Path(env)
    return Path(__file__).resolve().parents[2] / "target" / "parity"


@pytest.fixture(scope="module")
def fixture():
    d = _fixture_dir()
    manifest = d / "manifest.json"
    if not manifest.exists():
        pytest.skip(
            f"parity fixture missing at {d}; run `cargo test --test simd_parity` "
            "(export_python_parity_fixtures) first"
        )
    return d, json.loads(manifest.read_text())


def _read(d: Path, name: str, dtype):
    return np.fromfile(d / name, dtype=dtype)


def test_kind2_unpack_matches_rust_codes(fixture):
    """`unpack_codes` over Rust `to_i32_words()` output recovers the
    exact code stream Rust packed."""
    d, m = fixture
    spec = m["kind2"]
    n, bits = spec["n"], spec["bits"]
    words = _read(d, "kind2_words.bin", np.dtype("<i4"))
    codes = _read(d, "kind2_codes.bin", np.uint8)
    assert words.shape[0] == n * bits // 32
    got = np.asarray(pm.unpack_codes(jnp.asarray(words[None, :]), bits, n))[0]
    np.testing.assert_array_equal(got.astype(np.uint8), codes)


def test_kind2_dense_decode_byte_parity(fixture):
    """Pallas packed kernel (pre=0, one task, lam=1) byte-equals the
    Rust scalar dequant golden."""
    d, m = fixture
    spec = m["kind2"]
    n, group, bits = spec["n"], spec["group"], spec["bits"]
    words = jnp.asarray(_read(d, "kind2_words.bin", np.dtype("<i4"))[None, :])
    scales = jnp.asarray(_read(d, "kind2_scales.bin", np.dtype("<f4"))[None, :])
    zps = jnp.asarray(_read(d, "kind2_zps.bin", np.dtype("<f4"))[None, :])
    golden = _read(d, "kind2_golden.bin", np.dtype("<f4"))
    assert scales.shape[1] == spec["n_groups"] == n // group

    pre = jnp.zeros(n, dtype=jnp.float32)
    lams = jnp.ones(1, dtype=jnp.float32)
    got = np.asarray(
        pm.packed_dequant_merge(pre, words, scales, zps, lams, bits=bits, block=group),
        dtype=np.float32,
    )
    # Byte equality: identical IEEE bit patterns, not just allclose.
    np.testing.assert_array_equal(got.view(np.uint32), golden.view(np.uint32))


def test_kind4_sparse_decode_byte_parity(fixture):
    """Kind-4: unpack the survivor payload with the Python word decoder,
    dequantize in f32, scatter by the LSB-first bitmask — byte-equal to
    the Rust scalar decode."""
    d, m = fixture
    spec = m["kind4"]
    dense_len = spec["dense_len"]
    n_surv = spec["n_survivors"]
    padded = spec["padded_survivors"]
    group, bits = spec["group"], spec["bits"]
    mask = _read(d, "kind4_mask.bin", np.uint8)
    words = _read(d, "kind4_words.bin", np.dtype("<i4"))
    scales = _read(d, "kind4_scales.bin", np.dtype("<f4"))
    zps = _read(d, "kind4_zps.bin", np.dtype("<f4"))
    golden = _read(d, "kind4_golden.bin", np.dtype("<f4"))
    assert padded == spec["n_groups"] * group
    assert mask.shape[0] == (dense_len + 7) // 8

    q = np.asarray(pm.unpack_codes(jnp.asarray(words[None, :]), bits, padded))[0]
    # Same per-element arithmetic as the Rust scalar kernel:
    # scale * (code - zp), all in f32 (mul is commutative bit-exactly).
    q_f = q.astype(np.float32)
    zp_e = np.repeat(zps, group)
    scale_e = np.repeat(scales, group)
    vals = (q_f - zp_e) * scale_e

    # LSB-first mask bits -> survivor positions, ascending.
    bits_lsb = np.unpackbits(mask, bitorder="little")[:dense_len]
    positions = np.nonzero(bits_lsb)[0]
    assert positions.shape[0] == n_surv

    dense = np.zeros(dense_len, dtype=np.float32)
    # Rust dequantizes by accumulating into a zero buffer (`0.0 + v`),
    # which normalizes -0.0 to +0.0; replay the same op.
    dense[positions] = np.float32(0.0) + vals[:n_surv]
    np.testing.assert_array_equal(dense.view(np.uint32), golden.view(np.uint32))
