"""Layer-2 model checks: shapes, training signal, flattening contract."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def vit_s():
    cfg = M.VIT_PRESETS["vit_s"]
    return cfg, M.vit_init(cfg, seed=0)


def _batch(cfg, b, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, size=(b, cfg.tokens, cfg.token_dim))
                    .astype(np.float32))
    y = jnp.asarray(rng.integers(0, cfg.n_classes, size=b).astype(np.int32))
    head = jnp.asarray(rng.normal(0, cfg.dim ** -0.5,
                                  size=(cfg.dim, cfg.n_classes))
                       .astype(np.float32))
    return x, y, head


def test_vit_forward_shape(vit_s):
    cfg, p = vit_s
    x, _, head = _batch(cfg, 4)
    logits = M.vit_forward(cfg, p, head, x)
    assert logits.shape == (4, cfg.n_classes)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("preset", list(M.VIT_PRESETS))
def test_vit_param_counts_positive_and_ordered(preset):
    cfg = M.VIT_PRESETS[preset]
    p = M.vit_init(cfg)
    order = M.param_order(p)
    assert order == sorted(order)
    assert M.param_count(p) > 0
    assert M.flat_size_padded(p) % 1024 == 0
    assert M.flat_size_padded(p) >= M.param_count(p)


def test_vit_train_step_reduces_loss(vit_s):
    cfg, p = vit_s
    x, y, head = _batch(cfg, 32, seed=1)
    lr = jnp.array([0.5], jnp.float32)
    losses = []
    for _ in range(5):
        p, loss = M.vit_train_step(cfg, p, head, x, y, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_flatten_unflatten_roundtrip(vit_s):
    cfg, p = vit_s
    flat = M.flatten_params(p)
    back = M.unflatten_params(p, flat)
    for k in p:
        np.testing.assert_array_equal(np.asarray(p[k]), np.asarray(back[k]))


def test_merged_forward_consistent_with_plain_forward(vit_s):
    """TVQ merged-forward == forward(pre + sum dequantized tau)."""
    from compile.kernels import quantize as qz

    cfg, pre = vit_s
    t = 8
    rng = np.random.default_rng(3)
    pre_flat = M.flatten_params(pre)
    n = pre_flat.shape[0]
    g = n // qz.BLOCK
    qs, ss, zs = [], [], []
    taus = []
    for i in range(t):
        tau = jnp.asarray(rng.normal(0, 0.01, size=n).astype(np.float32))
        taus.append(tau)
        q, s, z = qz.quantize(tau, jnp.array([15.0], jnp.float32))
        qs.append(q)
        ss.append(s)
        zs.append(z)
    q, s, z = jnp.stack(qs), jnp.stack(ss), jnp.stack(zs)
    lams = jnp.full((t,), 0.3, jnp.float32)

    x, _, head = _batch(cfg, 32, seed=4)
    got = M.vit_merged_forward(cfg, pre, pre_flat, q, s, z, lams, head, x)

    # manual reference
    tau_hat = sum(
        0.3 * ((np.asarray(qs[i]).reshape(g, -1) - np.asarray(zs[i])[:, None])
               * np.asarray(ss[i])[:, None]).reshape(-1)
        for i in range(t)
    )
    merged = jnp.asarray(np.asarray(pre_flat) + tau_hat)
    want = M.vit_forward(cfg, M.unflatten_params(pre, merged), head, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_dense_forward_shapes():
    cfg = M.DENSE_PRESET
    p = M.dense_init(cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, size=(2, cfg.height, cfg.width, 3))
                    .astype(np.float32))
    for task, out_ch in M.DENSE_TASKS.items():
        head = jnp.asarray(rng.normal(0, 0.1, size=(1, 1, cfg.feat_ch, out_ch))
                           .astype(np.float32))
        out = M.dense_forward(cfg, p, head, x)
        assert out.shape == (2, cfg.height, cfg.width, out_ch), task


@pytest.mark.parametrize("task", list(M.DENSE_TASKS))
def test_dense_train_step_reduces_loss(task):
    cfg = M.DENSE_PRESET
    p = M.dense_init(cfg, seed=1)
    out_ch = M.DENSE_TASKS[task]
    rng = np.random.default_rng(2)
    b = 4
    x = jnp.asarray(rng.normal(0, 1, size=(b, cfg.height, cfg.width, 3))
                    .astype(np.float32))
    head = jnp.asarray(rng.normal(0, 0.2, size=(1, 1, cfg.feat_ch, out_ch))
                       .astype(np.float32))
    if task == "seg":
        y = jnp.asarray(rng.integers(0, cfg.seg_classes,
                                     size=(b, cfg.height, cfg.width))
                        .astype(np.int32))
    else:
        y = jnp.asarray(rng.normal(0, 1, size=(b, cfg.height, cfg.width, out_ch))
                        .astype(np.float32))
    lr = jnp.array([0.1], jnp.float32)
    losses = []
    for _ in range(5):
        p, loss = M.dense_train_step(cfg, task, p, head, x, y, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0], (task, losses)
