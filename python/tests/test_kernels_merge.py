"""Pallas fused dequant-merge kernel vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import dequant_merge as dqm
from compile.kernels import quantize as qz
from compile.kernels import ref


def _quantized_stack(t, n, bits, seed=0):
    rng = np.random.default_rng(seed)
    qmax = float(2 ** bits - 1)
    qs, ss, zs = [], [], []
    for i in range(t):
        x = jnp.asarray(rng.normal(0, 0.03, size=n).astype(np.float32))
        q, s, z = qz.quantize(x, jnp.array([qmax], jnp.float32))
        qs.append(q)
        ss.append(s)
        zs.append(z)
    return jnp.stack(qs), jnp.stack(ss), jnp.stack(zs)


@pytest.mark.parametrize("t", [1, 4, 8])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_dequant_merge_matches_ref(t, bits):
    n = 4096
    rng = np.random.default_rng(7)
    pre = jnp.asarray(rng.normal(0, 0.5, size=n).astype(np.float32))
    q, s, z = _quantized_stack(t, n, bits)
    lams = jnp.asarray(rng.uniform(0.1, 0.5, size=t).astype(np.float32))
    got = dqm.dequant_merge(pre, q, s, z, lams)
    want = ref.dequant_merge_ref(pre, q, s, z, lams)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_zero_lambda_returns_pre():
    n = 2048
    pre = jnp.linspace(-1, 1, n, dtype=jnp.float32)
    q, s, z = _quantized_stack(4, n, 4, seed=3)
    lams = jnp.zeros((4,), jnp.float32)
    got = dqm.dequant_merge(pre, q, s, z, lams)
    np.testing.assert_allclose(np.asarray(got), np.asarray(pre), atol=1e-6)


def test_single_task_equals_dequant_add():
    """T=1, lambda=1: merged == pre + dequantized tau."""
    n = 2048
    rng = np.random.default_rng(11)
    pre = jnp.asarray(rng.normal(0, 0.2, size=n).astype(np.float32))
    tau = jnp.asarray(rng.normal(0, 0.02, size=n).astype(np.float32))
    q, s, z = qz.quantize(tau, jnp.array([15.0], jnp.float32))
    got = dqm.dequant_merge(pre, q[None], s[None], z[None],
                            jnp.ones((1,), jnp.float32))
    g = n // qz.BLOCK
    tau_hat = ((np.asarray(q).reshape(g, -1) - np.asarray(z)[:, None])
               * np.asarray(s)[:, None]).reshape(-1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(pre) + tau_hat,
                               rtol=1e-5, atol=1e-6)


def test_rtvq_variant_matches_manual():
    """RTVQ path: base folded into pre + offsets via standard kernel."""
    n = 2048
    t = 4
    rng = np.random.default_rng(5)
    pre = jnp.asarray(rng.normal(0, 0.2, size=n).astype(np.float32))
    base = jnp.asarray(rng.normal(0, 0.05, size=n).astype(np.float32))
    qb, sb, zb = qz.quantize(base, jnp.array([7.0], jnp.float32))
    qo, so, zo = _quantized_stack(t, n, 2, seed=9)
    lams = jnp.full((t,), 0.3, jnp.float32)
    got = dqm.dequant_merge_rtvq(pre, qb, sb, zb, qo, so, zo, lams)

    g = n // qz.BLOCK
    base_hat = ((np.asarray(qb).reshape(g, -1) - np.asarray(zb)[:, None])
                * np.asarray(sb)[:, None]).reshape(-1)
    pre_eff = jnp.asarray(np.asarray(pre) + float(jnp.sum(lams)) * base_hat)
    want = ref.dequant_merge_ref(pre_eff, qo, so, zo, lams)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(min_value=1, max_value=8),
    blocks=st.integers(min_value=1, max_value=4),
    bits=st.sampled_from([2, 3, 4, 8]),
    seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
)
def test_hypothesis_merge_sweep(t, blocks, bits, seed):
    n = blocks * qz.BLOCK
    rng = np.random.default_rng(seed)
    pre = jnp.asarray(rng.normal(0, 1.0, size=n).astype(np.float32))
    q, s, z = _quantized_stack(t, n, bits, seed=seed)
    lams = jnp.asarray(rng.uniform(-1, 1, size=t).astype(np.float32))
    got = dqm.dequant_merge(pre, q, s, z, lams)
    want = ref.dequant_merge_ref(pre, q, s, z, lams)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
