"""AOT pipeline: lower every (model, entrypoint, batch) variant to HLO text.

Python's ONLY runtime role ends here: `make artifacts` runs this module
once, producing `artifacts/<name>.hlo.txt` (HLO text — NOT a serialized
HloModuleProto; the image's xla_extension 0.5.1 rejects jax>=0.5 64-bit
instruction ids, while the text parser reassigns ids and round-trips
cleanly) plus `artifacts/<name>.json` manifests describing the exact
input/output signature and the trunk-parameter flattening order the Rust
runtime must follow.  An `index.json` enumerates the whole artifact set.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import dequant_merge as dq
from .kernels import packed_merge as pk

BLOCK = dq.BLOCK

# Serving batch buckets per preset (the coordinator pads to the nearest
# bucket), plus the evaluation and training batch sizes.
SERVE_BUCKETS = {"vit_s": [1, 8, 32], "vit_m": [1, 32], "vit_l": [1, 32]}
EVAL_BATCH = 256
TRAIN_BATCH = 32
DENSE_BATCH = 8
MERGE_TASKS = 8  # T for the fused dequant-merge artifacts


def _dt(x) -> str:
    return {"float32": "f32", "int32": "i32"}[str(x)]


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class Artifact:
    """One lowered entrypoint: fn + example input specs + manifest extras."""

    def __init__(self, name: str, fn: Callable, inputs: List[dict],
                 params: Optional[List[dict]] = None, meta: Optional[dict] = None):
        self.name = name
        self.fn = fn
        self.inputs = inputs          # [{"name", "shape", "dtype"}]
        self.params = params          # trunk layout, if the entry takes one
        self.meta = meta or {}

    def lower(self):
        specs = [
            _spec(i["shape"], jnp.int32 if i["dtype"] == "i32" else jnp.float32)
            for i in self.inputs
        ]
        return jax.jit(self.fn).lower(*specs)


def _param_manifest(p: M.Params) -> List[dict]:
    return [{"name": k, "shape": list(p[k].shape)} for k in M.param_order(p)]


def _params_as_inputs(p: M.Params) -> List[dict]:
    return [
        {"name": f"param:{k}", "shape": list(p[k].shape), "dtype": "f32"}
        for k in M.param_order(p)
    ]


def vit_artifacts(preset: str) -> List[Artifact]:
    cfg = M.VIT_PRESETS[preset]
    tmpl = M.vit_init(cfg)
    pinputs = _params_as_inputs(tmpl)
    players = _param_manifest(tmpl)
    head = {"name": "head", "shape": [cfg.dim, cfg.n_classes], "dtype": "f32"}
    meta = {
        "preset": preset,
        "dim": cfg.dim,
        "depth": cfg.depth,
        "heads": cfg.heads,
        "tokens": cfg.tokens,
        "token_dim": cfg.token_dim,
        "n_classes": cfg.n_classes,
        "param_count": M.param_count(tmpl),
        "flat_padded": M.flat_size_padded(tmpl),
        "block": BLOCK,
    }

    def fwd(B):
        def f(*args):
            n = len(players)
            p = dict(zip(M.param_order(tmpl), args[:n]))
            return (M.vit_forward(cfg, p, args[n], args[n + 1]),)
        return f

    def train(B):
        def f(*args):
            n = len(players)
            p = dict(zip(M.param_order(tmpl), args[:n]))
            head_a, x, y, lr = args[n], args[n + 1], args[n + 2], args[n + 3]
            new_p, loss = M.vit_train_step(cfg, p, head_a, x, y, lr)
            return tuple(new_p[k] for k in M.param_order(tmpl)) + (loss,)
        return f

    arts = []
    batches = sorted(set(SERVE_BUCKETS[preset] + [EVAL_BATCH]))
    for b in batches:
        arts.append(Artifact(
            f"{preset}_forward_b{b}", fwd(b),
            pinputs + [head, {"name": "x", "shape": [b, cfg.tokens, cfg.token_dim], "dtype": "f32"}],
            params=players,
            meta={**meta, "entry": "forward", "batch": b},
        ))
    arts.append(Artifact(
        f"{preset}_train_b{TRAIN_BATCH}", train(TRAIN_BATCH),
        pinputs + [
            head,
            {"name": "x", "shape": [TRAIN_BATCH, cfg.tokens, cfg.token_dim], "dtype": "f32"},
            {"name": "y", "shape": [TRAIN_BATCH], "dtype": "i32"},
            {"name": "lr", "shape": [1], "dtype": "f32"},
        ],
        params=players,
        meta={**meta, "entry": "train", "batch": TRAIN_BATCH},
    ))
    return arts


def vit_merged_artifacts(preset: str) -> List[Artifact]:
    """Fused Pallas-dequant-merge + trunk forward (the serving fast path)."""
    cfg = M.VIT_PRESETS[preset]
    tmpl = M.vit_init(cfg)
    np_ = M.flat_size_padded(tmpl)
    g = np_ // BLOCK
    t = MERGE_TASKS
    b = SERVE_BUCKETS[preset][-1]

    def f(pre_flat, q, scales, zps, lams, head, x):
        return (M.vit_merged_forward(cfg, tmpl, pre_flat, q, scales, zps,
                                     lams, head, x),)

    inputs = [
        {"name": "pre_flat", "shape": [np_], "dtype": "f32"},
        {"name": "q", "shape": [t, np_], "dtype": "f32"},
        {"name": "scales", "shape": [t, g], "dtype": "f32"},
        {"name": "zps", "shape": [t, g], "dtype": "f32"},
        {"name": "lams", "shape": [t], "dtype": "f32"},
        {"name": "head", "shape": [cfg.dim, cfg.n_classes], "dtype": "f32"},
        {"name": "x", "shape": [b, cfg.tokens, cfg.token_dim], "dtype": "f32"},
    ]
    return [Artifact(
        f"{preset}_merged_forward_t{t}_b{b}", f, inputs,
        params=_param_manifest(tmpl),
        meta={"preset": preset, "entry": "merged_forward", "tasks": t,
              "batch": b, "flat_padded": np_, "block": BLOCK,
              "param_count": M.param_count(tmpl)},
    )]


def dense_artifacts() -> List[Artifact]:
    cfg = M.DENSE_PRESET
    tmpl = M.dense_init(cfg)
    pinputs = _params_as_inputs(tmpl)
    players = _param_manifest(tmpl)
    b = DENSE_BATCH
    meta = {
        "preset": "dense",
        "height": cfg.height,
        "width": cfg.width,
        "in_ch": cfg.in_ch,
        "ch": cfg.ch,
        "seg_classes": cfg.seg_classes,
        "param_count": M.param_count(tmpl),
        "flat_padded": M.flat_size_padded(tmpl),
        "block": BLOCK,
    }
    arts = []
    for task, out_ch in M.DENSE_TASKS.items():
        head = {"name": "head", "shape": [1, 1, cfg.feat_ch, out_ch], "dtype": "f32"}
        x_in = {"name": "x", "shape": [b, cfg.height, cfg.width, cfg.in_ch], "dtype": "f32"}

        def fwd(task=task):
            def f(*args):
                n = len(players)
                p = dict(zip(M.param_order(tmpl), args[:n]))
                return (M.dense_forward(cfg, p, args[n], args[n + 1]),)
            return f

        def train(task=task, out_ch=out_ch):
            y_shape = [b, cfg.height, cfg.width] if task == "seg" \
                else [b, cfg.height, cfg.width, out_ch]

            def f(*args):
                n = len(players)
                p = dict(zip(M.param_order(tmpl), args[:n]))
                head_a, x, y, lr = args[n], args[n + 1], args[n + 2], args[n + 3]
                new_p, loss = M.dense_train_step(cfg, task, p, head_a, x, y, lr)
                return tuple(new_p[k] for k in M.param_order(tmpl)) + (loss,)
            return f, y_shape

        arts.append(Artifact(
            f"dense_forward_{task}_b{b}", fwd(), pinputs + [head, x_in],
            params=players, meta={**meta, "entry": "forward", "task": task, "batch": b},
        ))
        tf, y_shape = train()
        ydt = "i32" if task == "seg" else "f32"
        arts.append(Artifact(
            f"dense_train_{task}_b{b}", tf,
            pinputs + [head, x_in,
                       {"name": "y", "shape": y_shape, "dtype": ydt},
                       {"name": "lr", "shape": [1], "dtype": "f32"}],
            params=players, meta={**meta, "entry": "train", "task": task, "batch": b},
        ))
    return arts


def kernel_artifacts() -> List[Artifact]:
    """Standalone Layer-1 kernel artifacts (validated against Rust natively)."""
    arts = []
    tmpl = M.vit_init(M.VIT_PRESETS["vit_s"])
    sizes = {"4k": 4096, "vit_s": M.flat_size_padded(tmpl)}
    for tag, n in sizes.items():
        g = n // BLOCK
        arts.append(Artifact(
            f"quantize_{tag}",
            lambda x, qmax: M.quantize_entry(x, qmax),
            [{"name": "x", "shape": [n], "dtype": "f32"},
             {"name": "qmax", "shape": [1], "dtype": "f32"}],
            meta={"entry": "quantize", "n": n, "groups": g, "block": BLOCK},
        ))
        t = MERGE_TASKS
        arts.append(Artifact(
            f"dequant_merge_{tag}_t{t}",
            lambda pre, q, s, z, l: (dq.dequant_merge(pre, q, s, z, l),),
            [{"name": "pre", "shape": [n], "dtype": "f32"},
             {"name": "q", "shape": [t, n], "dtype": "f32"},
             {"name": "scales", "shape": [t, g], "dtype": "f32"},
             {"name": "zps", "shape": [t, g], "dtype": "f32"},
             {"name": "lams", "shape": [t], "dtype": "f32"}],
            meta={"entry": "dequant_merge", "n": n, "groups": g,
                  "tasks": t, "block": BLOCK},
        ))
        # Packed-codes variant: int32 words, 32/bits codes per word — the
        # bandwidth-proportional payload path (see kernels/packed_merge.py).
        for bits in (2, 4, 8):
            cpw = 32 // bits
            nw = n // cpw
            arts.append(Artifact(
                f"packed_merge_{tag}_t{t}_b{bits}",
                (lambda bits_: lambda pre, w, s, z, l: (
                    pk.packed_dequant_merge(pre, w, s, z, l, bits=bits_),))(bits),
                [{"name": "pre", "shape": [n], "dtype": "f32"},
                 {"name": "words", "shape": [t, nw], "dtype": "i32"},
                 {"name": "scales", "shape": [t, g], "dtype": "f32"},
                 {"name": "zps", "shape": [t, g], "dtype": "f32"},
                 {"name": "lams", "shape": [t], "dtype": "f32"}],
                meta={"entry": "packed_merge", "n": n, "groups": g,
                      "tasks": t, "block": BLOCK, "bits": bits},
            ))
    return arts


def all_artifacts() -> List[Artifact]:
    arts: List[Artifact] = []
    for preset in M.VIT_PRESETS:
        arts.extend(vit_artifacts(preset))
    arts.extend(vit_merged_artifacts("vit_s"))
    arts.extend(dense_artifacts())
    arts.extend(kernel_artifacts())
    return arts


def emit(out_dir: str, only: Optional[str] = None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    index: Dict[str, dict] = {}
    index_path = os.path.join(out_dir, "index.json")
    if only and os.path.exists(index_path):
        # Partial re-lower: merge into the existing index instead of
        # clobbering entries for artifacts we are not regenerating.
        with open(index_path) as f:
            index = json.load(f)
    for art in all_artifacts():
        if only and only not in art.name:
            continue
        lowered = art.lower()
        text = to_hlo_text(lowered)
        hlo_path = os.path.join(out_dir, f"{art.name}.hlo.txt")
        with open(hlo_path, "w") as f:
            f.write(text)
        out_avals = jax.tree_util.tree_leaves(lowered.out_info)
        manifest = {
            "name": art.name,
            "inputs": art.inputs,
            "outputs": [
                {"shape": list(a.shape), "dtype": _dt(a.dtype)} for a in out_avals
            ],
            "params": art.params,
            "meta": art.meta,
            "hlo_sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        with open(os.path.join(out_dir, f"{art.name}.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        index[art.name] = {"meta": art.meta, "inputs": len(art.inputs),
                           "outputs": len(manifest["outputs"])}
        print(f"lowered {art.name}: {len(text)} chars, "
              f"{len(art.inputs)} in / {len(manifest['outputs'])} out")
    with open(os.path.join(out_dir, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print(f"wrote {len(index)} artifacts to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names")
    args = ap.parse_args()
    emit(args.out, args.only)


if __name__ == "__main__":
    main()
