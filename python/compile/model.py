"""Layer-2 JAX models for tvq-merge (build-time only).

Defines the model zoo whose checkpoints the paper merges:

  * A ViT-style transformer classifier at three scales (`vit_s`, `vit_m`,
    `vit_l`) standing in for CLIP ViT-B/32 / B/16 / L/14.  Per the paper's
    protocol only the TRUNK is fine-tuned and merged; each task owns a
    frozen classification head (the analog of CLIP's text-derived heads),
    which is therefore an *input* to every graph, not a parameter.
  * A dense-prediction conv encoder-decoder trunk (`dense`) with per-task
    1x1 heads for segmentation / depth / normal estimation (NYUv2 analog).

Every entrypoint (forward, train step, merged forward) is a pure function
over a flat `dict[str, Array]` of trunk parameters so the AOT pipeline can
emit a deterministic parameter manifest: Rust flattens checkpoints in
sorted-key order, which matches `param_order()` exactly.

The merged-forward entrypoints call the Layer-1 Pallas kernels, so the
fused dequantize-and-merge lowers into the same HLO as the model.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import dequant_merge as dq
from .kernels import quantize as qz

Params = Dict[str, jnp.ndarray]

# ---------------------------------------------------------------------------
# ViT classifier
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class VitConfig:
    """Transformer trunk configuration.

    tokens x token_dim synthetic "images" are produced by the Rust data
    generator; patch embedding is a linear map token_dim -> dim.
    """

    name: str
    dim: int
    depth: int
    heads: int
    mlp_ratio: int = 4
    tokens: int = 16
    token_dim: int = 16
    n_classes: int = 10

    @property
    def head_dim(self) -> int:
        return self.dim // self.heads


VIT_PRESETS = {
    "vit_s": VitConfig("vit_s", dim=64, depth=2, heads=4),
    "vit_m": VitConfig("vit_m", dim=128, depth=4, heads=4),
    "vit_l": VitConfig("vit_l", dim=192, depth=6, heads=6),
}


def vit_init(cfg: VitConfig, seed: int = 0) -> Params:
    """Deterministic init of the trunk parameter dict.

    Key names are chosen so that lexicographic order is stable and layers
    sort numerically (zero-padded indices).
    """
    rng = np.random.default_rng(seed)

    def dense_w(shape, fan_in):
        return jnp.asarray(
            rng.normal(0.0, fan_in ** -0.5, size=shape).astype(np.float32)
        )

    p: Params = {
        "embed/w": dense_w((cfg.token_dim, cfg.dim), cfg.token_dim),
        "embed/b": jnp.zeros((cfg.dim,), jnp.float32),
        "pos": jnp.asarray(
            rng.normal(0.0, 0.02, size=(cfg.tokens, cfg.dim)).astype(np.float32)
        ),
        "ln_f/g": jnp.ones((cfg.dim,), jnp.float32),
        "ln_f/b": jnp.zeros((cfg.dim,), jnp.float32),
    }
    hidden = cfg.dim * cfg.mlp_ratio
    for i in range(cfg.depth):
        pre = f"blk{i:02d}/"
        p[pre + "ln1/g"] = jnp.ones((cfg.dim,), jnp.float32)
        p[pre + "ln1/b"] = jnp.zeros((cfg.dim,), jnp.float32)
        p[pre + "attn/wq"] = dense_w((cfg.dim, cfg.dim), cfg.dim)
        p[pre + "attn/wk"] = dense_w((cfg.dim, cfg.dim), cfg.dim)
        p[pre + "attn/wv"] = dense_w((cfg.dim, cfg.dim), cfg.dim)
        p[pre + "attn/wo"] = dense_w((cfg.dim, cfg.dim), cfg.dim)
        p[pre + "attn/bo"] = jnp.zeros((cfg.dim,), jnp.float32)
        p[pre + "ln2/g"] = jnp.ones((cfg.dim,), jnp.float32)
        p[pre + "ln2/b"] = jnp.zeros((cfg.dim,), jnp.float32)
        p[pre + "mlp/w1"] = dense_w((cfg.dim, hidden), cfg.dim)
        p[pre + "mlp/b1"] = jnp.zeros((hidden,), jnp.float32)
        p[pre + "mlp/w2"] = dense_w((hidden, cfg.dim), hidden)
        p[pre + "mlp/b2"] = jnp.zeros((cfg.dim,), jnp.float32)
    return p


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(cfg: VitConfig, p: Params, pre: str, x):
    b, t, d = x.shape
    h, hd = cfg.heads, cfg.head_dim

    def split(w):
        return (x @ p[pre + w]).reshape(b, t, h, hd).transpose(0, 2, 1, 3)

    q, k, v = split("attn/wq"), split("attn/wk"), split("attn/wv")
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(hd).astype(np.float32)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ p[pre + "attn/wo"] + p[pre + "attn/bo"]


def vit_features(cfg: VitConfig, p: Params, x):
    """Trunk forward: x [B, tokens, token_dim] -> pooled features [B, dim]."""
    h = x @ p["embed/w"] + p["embed/b"] + p["pos"]
    for i in range(cfg.depth):
        pre = f"blk{i:02d}/"
        h = h + _attention(cfg, p, pre, _layer_norm(h, p[pre + "ln1/g"], p[pre + "ln1/b"]))
        m = _layer_norm(h, p[pre + "ln2/g"], p[pre + "ln2/b"])
        m = jax.nn.gelu(m @ p[pre + "mlp/w1"] + p[pre + "mlp/b1"])
        h = h + m @ p[pre + "mlp/w2"] + p[pre + "mlp/b2"]
    h = _layer_norm(h, p["ln_f/g"], p["ln_f/b"])
    return jnp.mean(h, axis=1)


def vit_forward(cfg: VitConfig, p: Params, head, x):
    """Classification logits with a frozen per-task head [dim, n_classes]."""
    return vit_features(cfg, p, x) @ head


def _cross_entropy(logits, y):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def vit_loss(cfg: VitConfig, p: Params, head, x, y):
    logits = vit_forward(cfg, p, head, x)
    return _cross_entropy(logits, y)


def vit_train_step(cfg: VitConfig, p: Params, head, x, y, lr):
    """One SGD step on the trunk (head frozen), returns (p', loss)."""
    loss, grads = jax.value_and_grad(lambda q: vit_loss(cfg, q, head, x, y))(p)
    new_p = jax.tree_util.tree_map(lambda w, g: w - lr[0] * g, p, grads)
    return new_p, loss


# ---------------------------------------------------------------------------
# Dense prediction conv trunk (NYUv2 analog)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DenseConfig:
    """Encoder-decoder trunk for HxW synthetic RGB scenes."""

    name: str = "dense"
    height: int = 16
    width: int = 16
    in_ch: int = 3
    ch: int = 24
    seg_classes: int = 6

    @property
    def feat_ch(self) -> int:
        return self.ch


DENSE_PRESET = DenseConfig()

# (task name, output channels) for the three NYUv2-analog tasks.
DENSE_TASKS = {"seg": DENSE_PRESET.seg_classes, "depth": 1, "normal": 3}


def dense_init(cfg: DenseConfig, seed: int = 0) -> Params:
    rng = np.random.default_rng(seed)

    def conv_w(kh, kw, cin, cout):
        fan = kh * kw * cin
        return jnp.asarray(
            rng.normal(0.0, fan ** -0.5, size=(kh, kw, cin, cout)).astype(np.float32)
        )

    c = cfg.ch
    return {
        "enc0/w": conv_w(3, 3, cfg.in_ch, c),
        "enc0/b": jnp.zeros((c,), jnp.float32),
        "enc1/w": conv_w(3, 3, c, 2 * c),
        "enc1/b": jnp.zeros((2 * c,), jnp.float32),
        "mid/w": conv_w(3, 3, 2 * c, 2 * c),
        "mid/b": jnp.zeros((2 * c,), jnp.float32),
        "dec0/w": conv_w(3, 3, 2 * c, c),
        "dec0/b": jnp.zeros((c,), jnp.float32),
        "dec1/w": conv_w(3, 3, 2 * c, c),
        "dec1/b": jnp.zeros((c,), jnp.float32),
    }


def _conv(x, w, b, stride=1):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + b


def dense_features(cfg: DenseConfig, p: Params, x):
    """Trunk forward: x [B,H,W,3] -> per-pixel features [B,H,W,ch]."""
    e0 = jax.nn.relu(_conv(x, p["enc0/w"], p["enc0/b"]))             # H
    e1 = jax.nn.relu(_conv(e0, p["enc1/w"], p["enc1/b"], stride=2))  # H/2
    m = jax.nn.relu(_conv(e1, p["mid/w"], p["mid/b"]))               # H/2
    up = jax.image.resize(m, e0.shape[:3] + (m.shape[-1],), "nearest")
    d0 = jax.nn.relu(_conv(up, p["dec0/w"], p["dec0/b"]))            # H
    cat = jnp.concatenate([d0, e0], axis=-1)
    return jax.nn.relu(_conv(cat, p["dec1/w"], p["dec1/b"]))         # [B,H,W,ch]


def dense_forward(cfg: DenseConfig, p: Params, head, x):
    """Per-task prediction with a frozen 1x1 head [1,1,ch,out_ch]."""
    feats = dense_features(cfg, p, x)
    return _conv(feats, head, jnp.zeros((head.shape[-1],), jnp.float32))


def dense_loss(cfg: DenseConfig, task: str, p: Params, head, x, y):
    out = dense_forward(cfg, p, head, x)
    if task == "seg":
        logp = jax.nn.log_softmax(out, axis=-1)
        yy = y.astype(jnp.int32)
        return -jnp.mean(jnp.take_along_axis(logp, yy[..., None], axis=-1))
    if task == "depth":
        return jnp.mean(jnp.abs(out - y))
    if task == "normal":
        # 1 - cosine similarity between predicted and target normals.
        num = jnp.sum(out * y, axis=-1)
        den = jnp.linalg.norm(out, axis=-1) * jnp.linalg.norm(y, axis=-1) + 1e-6
        return jnp.mean(1.0 - num / den)
    raise ValueError(task)


def dense_train_step(cfg: DenseConfig, task: str, p: Params, head, x, y, lr):
    loss, grads = jax.value_and_grad(lambda q: dense_loss(cfg, task, q, head, x, y))(p)
    new_p = jax.tree_util.tree_map(lambda w, g: w - lr[0] * g, p, grads)
    return new_p, loss


# ---------------------------------------------------------------------------
# Parameter flattening contract shared with the Rust runtime
# ---------------------------------------------------------------------------


def param_order(p: Params):
    """Deterministic (sorted-key) parameter order used by all artifacts."""
    return sorted(p.keys())


def param_count(p: Params) -> int:
    return sum(int(np.prod(v.shape)) for v in p.values())


def flat_size_padded(p: Params, block: int = dq.BLOCK) -> int:
    """Flattened parameter length padded up to the Pallas block size."""
    n = param_count(p)
    return ((n + block - 1) // block) * block


def flatten_params(p: Params, block: int = dq.BLOCK):
    """Concatenate in manifest order and zero-pad to the block multiple."""
    flat = jnp.concatenate([p[k].reshape(-1) for k in param_order(p)])
    pad = flat_size_padded(p, block) - flat.shape[0]
    return jnp.pad(flat, (0, pad))


def unflatten_params(template: Params, flat):
    out = {}
    off = 0
    for k in param_order(template):
        sz = int(np.prod(template[k].shape))
        out[k] = flat[off : off + sz].reshape(template[k].shape)
        off += sz
    return out


# ---------------------------------------------------------------------------
# Merged-forward entrypoints: Pallas dequant-merge fused into the model HLO
# ---------------------------------------------------------------------------


def vit_merged_forward(cfg: VitConfig, template: Params, pre_flat, q, scales,
                       zps, lams, head, x):
    """Serve a batch straight from quantized task vectors (TVQ path).

    pre_flat [Np] / q [T,Np] / scales,zps [T,G] / lams [T] as in the
    Layer-1 kernel; the merged flat vector is unflattened and fed through
    the standard trunk.  This lowers kernel + model into one HLO module.
    """
    merged = dq.dequant_merge(pre_flat, q, scales, zps, lams)
    p = unflatten_params(template, merged)
    return vit_forward(cfg, p, head, x)


def quantize_entry(x, qmax):
    """Artifact wrapper for the Layer-1 quantization path."""
    return qz.quantize(x, qmax)
