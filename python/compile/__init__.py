"""Build-time compile package for tvq-merge (never imported at runtime)."""
