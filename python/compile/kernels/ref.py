"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal for Layer 1: every Pallas kernel in
this package is checked against the functions here by pytest (+hypothesis)
at build time.  They also double as the executable specification of the
paper's quantizer (Eq. 1-2) and of the fused dequantize-and-merge operator
used by the serving coordinator.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "quant_params_ref",
    "quantize_ref",
    "dequantize_ref",
    "dequant_merge_ref",
    "group_quant_params_ref",
    "group_quantize_ref",
]


def quant_params_ref(x: jnp.ndarray, qmax: float):
    """Asymmetric quantization parameters (Eq. 1 of the paper).

    Returns (scale, zero_point) mapping [min(x), max(x)] onto [0, qmax].
    A degenerate range (constant tensor c) yields scale=|c| (or 1 for c=0)
    so that dequantization reproduces the constant exactly.
    """
    xmin = jnp.min(x)
    xmax = jnp.max(x)
    span = xmax - xmin
    degen = jnp.where(jnp.abs(xmin) > 0, jnp.abs(xmin), 1.0)
    scale = jnp.where(span > 0, span / qmax, degen)
    zp = jnp.round(-xmin / scale)
    return scale, zp


def quantize_ref(x: jnp.ndarray, scale: jnp.ndarray, zp: jnp.ndarray, qmax: float):
    """Asymmetric affine quantization: q = clip(round(x/scale) + zp, 0, qmax)."""
    q = jnp.round(x / scale) + zp
    return jnp.clip(q, 0.0, qmax)


def dequantize_ref(q: jnp.ndarray, scale: jnp.ndarray, zp: jnp.ndarray):
    """Eq. 2: theta_hat = scale * (q - zp)."""
    return scale * (q - zp)


def dequant_merge_ref(pre, q, scales, zps, lams):
    """Fused dequantize-and-merge (the serving hot spot).

    pre    : [N]    pre-trained parameter vector block
    q      : [T, N] quantized task vectors (integer values stored as f32)
    scales : [T, G] per-group scales, groups of size N // G
    zps    : [T, G] per-group zero points
    lams   : [T]    merging coefficients

    Returns theta_merged = pre + sum_t lam_t * scale_t * (q_t - zp_t).
    """
    t, n = q.shape
    g = scales.shape[1]
    group = n // g
    qg = q.reshape(t, g, group)
    deltas = (qg - zps[:, :, None]) * scales[:, :, None]
    merged = pre + jnp.einsum("t,tgc->gc", lams, deltas).reshape(n)
    return merged


def group_quant_params_ref(x: jnp.ndarray, groups: int, qmax: float):
    """Per-group (a.k.a. blockwise) quantization parameters.

    x is [N]; returns (scales [G], zps [G]) with G = groups.
    """
    xg = x.reshape(groups, -1)
    xmin = jnp.min(xg, axis=1)
    xmax = jnp.max(xg, axis=1)
    span = xmax - xmin
    degen = jnp.where(jnp.abs(xmin) > 0, jnp.abs(xmin), 1.0)
    scales = jnp.where(span > 0, span / qmax, degen)
    zps = jnp.round(-xmin / scales)
    return scales, zps


def group_quantize_ref(x: jnp.ndarray, scales, zps, qmax: float):
    """Per-group asymmetric quantization of a flat [N] vector."""
    g = scales.shape[0]
    xg = x.reshape(g, -1)
    q = jnp.round(xg / scales[:, None]) + zps[:, None]
    return jnp.clip(q, 0.0, qmax).reshape(-1)
