"""Layer-1 Pallas kernels for tvq-merge.

`quantize`      - asymmetric per-group quantization (Eq. 1).
`dequant_merge` - fused dequantize-and-merge of T quantized task vectors.
`ref`           - pure-jnp oracles; the correctness contract for both.
"""

from . import dequant_merge, quantize, ref  # noqa: F401
