"""Pallas fused dequantize-and-merge kernel (Layer 1).

This is the deployment hot spot of the paper's pipeline: reconstructing a
merged parameter vector

    theta_merged = theta_pre + sum_t lam_t * scale_t * (q_t - zp_t)

directly from the quantized task-vector payloads, without materializing any
intermediate full-precision task vector.  One grid step processes one
lane-aligned block of the parameter vector for ALL tasks, so the packed
task payloads stream through VMEM exactly once.

TPU mapping (documented; executed under interpret=True on this image):
  * block of BLOCK f32 per task -> a [T, BLOCK] VMEM tile per step;
  * per-group scale/zp arrive as [T, 1] scalars alongside each tile;
  * fp32 accumulate on the VPU; no MXU;
  * VMEM per step = (T + 2) * BLOCK * 4 B  (T task tiles + pre + out),
    e.g. T=8, BLOCK=1024 -> 40 KiB, far below the 16 MiB budget, leaving
    room for multi-buffered HBM->VMEM pipelining on real hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _dequant_merge_kernel(pre_ref, q_ref, scale_ref, zp_ref, lam_ref, o_ref):
    """One parameter block: out = pre + sum_t lam_t*scale_t*(q_t - zp_t)."""
    pre = pre_ref[...]          # [BLOCK]
    q = q_ref[...]              # [T, BLOCK]
    scale = scale_ref[...]      # [T, 1]
    zp = zp_ref[...]            # [T, 1]
    lam = lam_ref[...]          # [T]
    contrib = (q - zp) * (scale * lam[:, None])
    o_ref[...] = pre + jnp.sum(contrib, axis=0)


@functools.partial(jax.jit, static_argnames=("block",))
def dequant_merge(pre, q, scales, zps, lams, block: int = BLOCK):
    """Fused dequantize-and-merge over a flat parameter vector.

    pre    : [N] f32 pre-trained parameters
    q      : [T, N] f32 quantized task-vector values (integers in f32)
    scales : [T, G] f32 per-group scales, G = N // block
    zps    : [T, G] f32 per-group zero points
    lams   : [T] f32 merging coefficients

    Returns [N] f32 merged parameters.
    """
    t, n = q.shape
    g = n // block
    return pl.pallas_call(
        _dequant_merge_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((t, block), lambda i: (0, i)),
            pl.BlockSpec((t, 1), lambda i: (0, i)),
            pl.BlockSpec((t, 1), lambda i: (0, i)),
            pl.BlockSpec((t,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(pre, q, scales, zps, lams)


def dequant_merge_rtvq(pre, q_base, s_base, z_base, q_off, s_off, z_off, lams,
                       block: int = BLOCK):
    """RTVQ variant: tau_t = dq(base) + dq(offset_t)  (Alg. 1, line 5).

    The shared base vector is dequantized once and folded into `pre`
    (scaled by sum_t lam_t); the per-task offsets then follow the standard
    fused path.  q_base/s_base/z_base are [N]/[G]/[G]; offsets as in
    `dequant_merge`.
    """
    g = s_base.shape[0]
    group = pre.shape[0] // g
    base = ((q_base.reshape(g, group) - z_base[:, None]) * s_base[:, None])
    pre_eff = pre + jnp.sum(lams) * base.reshape(-1)
    return dequant_merge(pre_eff, q_off, s_off, z_off, lams, block=block)
