"""Pallas packed-codes dequantize-and-merge kernel (Layer 1, extension).

`dequant_merge.py` streams codes as f32 — simple, but each 2/4/8-bit code
costs 4 bytes of HBM->VMEM bandwidth.  This kernel takes the codes in
their PACKED form (int32 words holding 32/bits codes each) and unpacks
in-register with shifts and masks, so the payload traffic shrinks by
32/bits x — the same bandwidth story the Rust `BitPacked` container
realizes on the coordinator side, now inside the XLA graph.

Supported widths: bits in {2, 4, 8} (dividing 32, so no word straddling —
exactly the layout `GroupQuantized` uses for those widths).

TPU mapping (documented; executed under interpret=True on this image):
  * grid step i owns one [T, BLOCK/cpw] int32 word tile (VMEM) per task
    plus the [BLOCK] f32 pre tile;
  * unpack = cpw shift/and ops on the VPU, fused with the dequant FMA;
  * VMEM per step = T*BLOCK*4/cpw (codes) + 2*BLOCK*4 (pre/out) bytes —
    e.g. T=8, BLOCK=1024, 4-bit: 12 KiB vs 40 KiB for the f32-code path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def pack_codes(q, bits: int):
    """Pack integer codes (f32 or int array, values < 2^bits) into int32
    words little-endian, `32 // bits` codes per word.  Reference packer
    for tests and the AOT input convention; mirrors rust `BitPacked` for
    widths dividing 32.
    """
    if 32 % bits != 0:
        raise ValueError(f"bits={bits} must divide 32")
    cpw = 32 // bits
    q = jnp.asarray(q, jnp.int32)
    *lead, n = q.shape
    if n % cpw != 0:
        raise ValueError(f"n={n} not a multiple of codes-per-word {cpw}")
    qw = q.reshape(*lead, n // cpw, cpw)
    shifts = jnp.arange(cpw, dtype=jnp.int32) * bits
    return jnp.sum(qw << shifts, axis=-1).astype(jnp.int32)


def unpack_codes(words, bits: int, n: int):
    """Inverse of `pack_codes` (pure-jnp reference)."""
    cpw = 32 // bits
    mask = (1 << bits) - 1
    shifts = jnp.arange(cpw, dtype=jnp.int32) * bits
    codes = (words[..., None] >> shifts) & mask
    return codes.reshape(*words.shape[:-1], words.shape[-1] * cpw)[..., :n]


def packed_dequant_merge_ref(pre, words, scales, zps, lams, bits: int):
    """Pure-jnp oracle: unpack then the standard fused merge."""
    t, nw = words.shape
    n = pre.shape[0]
    q = unpack_codes(words, bits, n).astype(jnp.float32)
    g = scales.shape[1]
    group = n // g
    qg = q.reshape(t, g, group)
    deltas = (qg - zps[:, :, None]) * scales[:, :, None]
    return pre + jnp.einsum("t,tgc->gc", lams, deltas).reshape(n)


def _packed_kernel(bits, pre_ref, w_ref, scale_ref, zp_ref, lam_ref, o_ref):
    """One parameter block, codes arriving packed in int32 words."""
    cpw = 32 // bits
    mask = (1 << bits) - 1
    pre = pre_ref[...]            # [BLOCK]
    words = w_ref[...]            # [T, BLOCK // cpw] int32
    scale = scale_ref[...]        # [T, 1]
    zp = zp_ref[...]              # [T, 1]
    lam = lam_ref[...]            # [T]
    t = words.shape[0]
    shifts = jnp.arange(cpw, dtype=jnp.int32) * bits
    q = ((words[:, :, None] >> shifts) & mask).reshape(t, -1).astype(jnp.float32)
    contrib = (q - zp) * (scale * lam[:, None])
    o_ref[...] = pre + jnp.sum(contrib, axis=0)


@functools.partial(jax.jit, static_argnames=("bits", "block"))
def packed_dequant_merge(pre, words, scales, zps, lams, bits: int,
                         block: int = BLOCK):
    """Fused unpack + dequantize + merge over a flat parameter vector.

    pre    : [N] f32
    words  : [T, N*bits/32] int32 packed codes
    scales : [T, G] f32, G = N // block
    zps    : [T, G] f32
    lams   : [T] f32

    Returns [N] f32 merged parameters.
    """
    if 32 % bits != 0:
        raise ValueError(f"bits={bits} must divide 32")
    cpw = 32 // bits
    t, nw = words.shape
    n = pre.shape[0]
    assert nw * cpw == n, f"packed length mismatch: {nw}*{cpw} != {n}"
    g = n // block
    wblock = block // cpw
    kernel = functools.partial(_packed_kernel, bits)
    return pl.pallas_call(
        kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((t, wblock), lambda i: (0, i)),
            pl.BlockSpec((t, 1), lambda i: (0, i)),
            pl.BlockSpec((t, 1), lambda i: (0, i)),
            pl.BlockSpec((t,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(pre, words, scales, zps, lams)
