"""Pallas asymmetric quantization kernel (Layer 1).

The kernel performs the round/clip stage of Eq. 1 over lane-aligned blocks
of a flat parameter vector; per-group (scale, zero-point) statistics are
reduced outside the kernel (a cheap one-pass jnp reduction that XLA fuses)
and streamed in one group per grid step.

TPU mapping (documented here, executed under interpret=True on this image):
  * grid step i owns one VMEM block of BLOCK f32 weights (BLOCK = 8 * 128
    lanes by default, sublane x lane aligned);
  * scale/zp for the group live in SMEM-like (1,) blocks;
  * pure VPU elementwise work - no MXU involvement;
  * VMEM footprint per step: 2 * BLOCK * 4 B (in + out) + O(1) scalars.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# 8 sublanes x 128 lanes: the natural f32 tile on TPU.
BLOCK = 1024


def _quantize_kernel(x_ref, scale_ref, zp_ref, qmax_ref, o_ref):
    """q = clip(round(x / scale) + zp, 0, qmax) for one group block."""
    x = x_ref[...]
    scale = scale_ref[0]
    zp = zp_ref[0]
    qmax = qmax_ref[0]
    q = jnp.round(x / scale) + zp
    o_ref[...] = jnp.clip(q, 0.0, qmax)


@functools.partial(jax.jit, static_argnames=("block",))
def quantize_blocks(x, scales, zps, qmax, block: int = BLOCK):
    """Pallas round/clip over a flat vector with per-group statistics.

    x      : [N] f32, N divisible by block
    scales : [G] f32 with G = N // block
    zps    : [G] f32
    qmax   : [1] f32 (2^bits - 1) - runtime input so one artifact serves
             every bit width
    """
    n = x.shape[0]
    g = n // block
    return pl.pallas_call(
        _quantize_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, scales, zps, qmax)


def quantize(x, qmax, block: int = BLOCK):
    """Full per-group quantization path: stats (jnp) + round/clip (Pallas).

    Returns (q [N], scales [G], zps [G]).  This is the function lowered to
    the `quantize` artifact; `qmax` arrives as a [1] f32 tensor.
    """
    n = x.shape[0]
    g = n // block
    scales, zps = ref.group_quant_params_ref(x, g, qmax[0])
    q = quantize_blocks(x, scales, zps, qmax, block=block)
    return q, scales, zps
